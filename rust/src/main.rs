//! `alps` — CLI for the ALPS one-shot pruning system.
//!
//! Subcommands:
//!   prune  --model alps-base --sparsity 0.7 --method alps [--engine hlo]
//!          [--out pruned.bin]                prune a model end-to-end
//!   eval   --model alps-base [--weights pruned.bin]
//!          perplexity on the three eval splits + 4 zero-shot tasks
//!   layer  --model alps-base --layer mlp.w2 --sparsity 0.7 [--methods all]
//!          single-layer reconstruction-error comparison (Fig. 2 row)
//!   serve  --model alps-base --weights pruned.bin [--sparse] [--stdin]
//!          continuous-batching generation server (see serve/mod.rs)
//!   info                                      artifact + model inventory
//!   smoke  <file.hlo.txt>                     runtime smoke test

use alps::config::{AlpsConfig, ModelConfig, SparsityTarget};
use alps::coordinator::{PruneEngine, Scheduler};
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::{Model, Weights};
use alps::pruning::{all_methods, method_by_name};
use alps::runtime::{artifact, Runtime};
use alps::serve::tcp::{fmt_tokens, parse_prompt};
use alps::serve::{Batcher, Engine, SamplingParams, TcpConfig};
use alps::util::table::{fmt_sig, Table};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Minimal flag parser: --key value pairs plus positional args.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn artifacts_dir() -> PathBuf {
    artifact::default_dir()
}

fn load_model(args: &Args) -> Result<Model> {
    let name = args.get("model", "alps-tiny");
    let dir = artifacts_dir();
    let mut model = Model::load(&dir, &name)
        .with_context(|| format!("loading model '{name}' from {dir:?}"))?;
    if args.has("weights") {
        let w = Weights::load(&PathBuf::from(args.get("weights", "")))?;
        model.weights = w;
    }
    Ok(model)
}

fn load_calib(model: &Model, n: usize) -> Result<Vec<Vec<u16>>> {
    let corpus = Corpus::load(&artifacts_dir().join("corpus.bin"))?;
    let train = corpus.split("train")?;
    Ok(sample_windows(train, n, model.cfg.seq_len, 0xCA11B))
}

fn cmd_prune(args: &Args) -> Result<()> {
    let mut model = load_model(args)?;
    let target = SparsityTarget::parse(&args.get("sparsity", "0.7"))?;
    let method = args.get("method", "alps");
    let n_calib = args.get("calib", "32").parse::<usize>()?;
    let calib = load_calib(&model, n_calib)?;
    let mut sched = Scheduler::new(calib);
    sched.verbose = !args.has("quiet");

    println!(
        "pruning {} ({} params) to {} with {}",
        model.cfg.name,
        model.weights.total_params(),
        target.label(),
        method
    );
    let report = if args.get("engine", "native") == "hlo" {
        if method != "alps" {
            bail!("--engine hlo only supports --method alps");
        }
        let rt = Runtime::new(&artifacts_dir())?;
        let engine = PruneEngine::Hlo(&rt, AlpsConfig::default());
        let r = sched.prune_model(&mut model, target, &engine)?;
        println!("(hlo engine: {} artifact executions)", rt.total_execs());
        r
    } else {
        method_by_name(&method)?; // validate early
        sched.prune_model(&mut model, target, &PruneEngine::Native(method.clone()))?
    };
    println!("{}", report.summary());

    let out = args.get("out", "");
    if !out.is_empty() {
        model.weights.save(&PathBuf::from(&out))?;
        println!("wrote pruned weights to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let corpus = Corpus::load(&artifacts_dir().join("corpus.bin"))?;
    let n_items = args.get("items", "50").parse::<usize>()?;

    let mut t = Table::new(&["dataset", "metric", "value"]);
    for split in Corpus::eval_split_names() {
        let ids = corpus.split(split)?;
        let ppl = perplexity(&model, ids)?;
        t.row(&[split.to_string(), "ppl".into(), fmt_sig(ppl)]);
    }
    let test_ids = corpus.split("wikitext2-like")?;
    for task in tasks::standard_tasks(test_ids, n_items, model.cfg.seq_len, model.cfg.vocab, 7) {
        let acc = zero_shot_accuracy(&model, &task)?;
        t.row(&[task.name.to_string(), "acc%".into(), format!("{:.2}", acc * 100.0)]);
    }
    let names = model.prunable_names();
    println!(
        "model {} — prunable sparsity {:.3}",
        model.cfg.name,
        model.weights.sparsity_of(&names)
    );
    t.print();
    Ok(())
}

fn cmd_layer(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let layer = args.get("layer", "mlp.w2");
    let block = args.get("block", "0").parse::<usize>()?;
    let calib = load_calib(&model, args.get("calib", "32").parse()?)?;
    let p = alps::coordinator::scheduler::single_layer_problem(&model, &calib, block, &layer)?;
    let target = SparsityTarget::parse(&args.get("sparsity", "0.7"))?;

    println!(
        "layer blocks.{block}.{layer} ({}x{}), target {}",
        p.n_in(),
        p.n_out(),
        target.label()
    );
    let mut t = Table::new(&["method", "rel-error", "nnz", "secs"]);
    let methods = if args.get("methods", "all") == "all" {
        all_methods()
    } else {
        args.get("methods", "alps")
            .split(',')
            .map(method_by_name)
            .collect::<Result<Vec<_>>>()?
    };
    for m in methods {
        let timer = alps::util::Timer::start();
        let w = m.prune(&p, target)?;
        let secs = timer.elapsed_secs();
        t.row(&[
            m.name().to_string(),
            fmt_sig(p.rel_error(&w)),
            w.nnz().to_string(),
            format!("{secs:.2}"),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get("model", "alps-tiny");
    let model = if args.has("random") {
        // synthetic weights: lets the server run without built artifacts
        Model::random(ModelConfig::preset(&name)?, 0xA125)?
    } else {
        load_model(args)?
    };
    let engine = if args.has("sparse") {
        Engine::sparse(&model)?
    } else {
        Engine::dense(&model)?
    };
    let stop_token = match args.flags.get("stop") {
        Some(s) => Some(s.parse::<u16>().context("--stop token id")?),
        None => None,
    };
    let params = SamplingParams {
        max_new_tokens: args.get("max-new", "32").parse().context("--max-new")?,
        temperature: args.get("temperature", "0").parse().context("--temperature")?,
        top_k: args.get("top-k", "0").parse().context("--top-k")?,
        stop_token,
    };
    let cfg = TcpConfig {
        max_batch: args.get("max-batch", "8").parse().context("--max-batch")?,
        max_conns: args.get("max-conns", "64").parse().context("--max-conns")?,
        max_line_bytes: args.get("max-line", "65536").parse().context("--max-line")?,
    };
    println!(
        "serving {} [{}] — vocab {}, ctx {}, max batch {}, threads {}",
        model.cfg.name,
        engine.label(),
        model.cfg.vocab,
        model.cfg.seq_len,
        cfg.max_batch,
        alps::linalg::matmul::num_threads(),
    );
    if args.has("stdin") {
        serve_stdin(&engine, &params, cfg.max_batch)
    } else {
        serve_tcp(&engine, &params, &cfg, &args.get("addr", "127.0.0.1:7878"))
    }
}

/// Batch every prompt line from stdin through the continuous batcher,
/// print `<id>: <tokens>` lines plus the metrics table.
fn serve_stdin(engine: &Engine, params: &SamplingParams, max_batch: usize) -> Result<()> {
    let mut batcher = Batcher::new(engine, max_batch);
    for line in std::io::stdin().lines() {
        let line = line.context("reading stdin")?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_prompt(line) {
            Ok(p) => {
                batcher.submit(p, params.clone());
            }
            Err(e) => eprintln!("skipping line: {e}"),
        }
    }
    let mut responses = batcher.run_to_completion()?;
    responses.sort_by_key(|r| r.id);
    for r in responses {
        match r.error {
            Some(e) => println!("{}: ERR {e}", r.id),
            None => println!("{}: {}", r.id, fmt_tokens(&r.tokens)),
        }
    }
    println!("{}", batcher.metrics.render());
    Ok(())
}

/// Threaded multi-connection line protocol over TCP — see
/// `alps::serve::tcp` for the protocol and threading model. Runs until a
/// client sends `shutdown`, then prints the final metrics report.
fn serve_tcp(
    engine: &Engine,
    params: &SamplingParams,
    cfg: &TcpConfig,
    addr: &str,
) -> Result<()> {
    let listener =
        std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "listening on {addr} — up to {} connections; prompt lines ack `queued <id>`, \
         blank line or `run` waits for results, `stats` for metrics, `shutdown` stops; \
         GET /healthz for status",
        cfg.max_conns
    );
    let report = alps::serve::tcp::serve(listener, engine, params, cfg)?;
    println!("{report}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = artifacts_dir();
    println!("artifacts dir: {dir:?}");
    match alps::runtime::Manifest::load(&dir) {
        Ok(m) => {
            let mut kinds: HashMap<String, usize> = HashMap::new();
            for a in m.artifacts.values() {
                *kinds.entry(a.kind.clone()).or_insert(0) += 1;
            }
            println!("{} artifacts:", m.artifacts.len());
            let mut ks: Vec<_> = kinds.into_iter().collect();
            ks.sort();
            for (k, n) in ks {
                println!("  {k}: {n}");
            }
        }
        Err(e) => println!("no manifest: {e}"),
    }
    for preset in ["alps-tiny", "alps-small", "alps-base"] {
        match Model::load(&dir, preset) {
            Ok(m) => println!(
                "model {preset}: {} params, {} blocks",
                m.weights.total_params(),
                m.cfg.n_layers
            ),
            Err(_) => println!("model {preset}: not built (run `make artifacts`)"),
        }
    }
    match Corpus::load(&dir.join("corpus.bin")) {
        Ok(c) => println!(
            "corpus: vocab {}, splits {:?}",
            c.vocab.len(),
            c.splits.iter().map(|(k, v)| format!("{k}:{}", v.len())).collect::<Vec<_>>()
        ),
        Err(_) => println!("corpus: not built"),
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "artifacts/smoke.hlo.txt".to_string());
    let out = alps::runtime::smoke::run_hlo_f32(
        &path,
        &[
            ((0..24).map(|i| i as f32).collect(), vec![4, 6]),
            ((0..24).map(|i| (23 - i) as f32 * 0.5).collect(), vec![4, 6]),
        ],
        Some(7),
    )?;
    for (i, v) in out.iter().enumerate() {
        let head: Vec<f32> = v.iter().take(8).cloned().collect();
        println!("out[{i}] len={} head={head:?}", v.len());
    }
    println!("smoke OK");
    Ok(())
}

fn usage() {
    println!(
        "alps — ADMM-based one-shot LLM pruning (NeurIPS 2024 reproduction)\n\
         usage: alps <prune|eval|layer|serve|info|smoke> [flags]\n\
           prune --model alps-base --sparsity 0.7|2:4 --method alps|mp|wanda|sparsegpt|dsnot\n\
                 [--engine native|hlo] [--calib 32] [--out pruned.bin] [--quiet]\n\
           eval  --model alps-base [--weights pruned.bin] [--items 50]\n\
           layer --model alps-base --block 0 --layer mlp.w2 --sparsity 0.7 [--methods all]\n\
           serve --model alps-base [--weights pruned.bin] [--sparse] [--random]\n\
                 [--addr 127.0.0.1:7878 | --stdin] [--max-batch 8] [--max-conns 64]\n\
                 [--max-line 65536] [--max-new 32] [--temperature 0] [--top-k 0] [--stop id]\n\
           info\n\
           smoke [file.hlo.txt]"
    );
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "prune" => cmd_prune(&args),
        "eval" => cmd_eval(&args),
        "layer" => cmd_layer(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        "smoke" => cmd_smoke(&args),
        _ => {
            usage();
            bail!("unknown command '{cmd}'");
        }
    }
}
