//! `alps` — CLI for the ALPS one-shot pruning system.
//!
//! Subcommands:
//!   prune  --model alps-base --sparsity 0.7 --method alps [--engine hlo]
//!          [--out pruned.bin]                prune a model end-to-end
//!   eval   --model alps-base [--weights pruned.bin]
//!          perplexity on the three eval splits + 4 zero-shot tasks
//!   layer  --model alps-base --layer mlp.w2 --sparsity 0.7 [--methods all]
//!          single-layer reconstruction-error comparison (Fig. 2 row)
//!   serve  --model alps-base --weights pruned.bin [--stdin]
//!          [--format dense|csr|nm[:N:M]|int8]  (--sparse = --format csr)
//!          continuous-batching generation server (see serve/mod.rs);
//!          `nm` serves the packed N:M format from `alps::sparse`;
//!          `int8` serves quantized codes + per-column scales
//!   worker --addr 127.0.0.1:7979              distributed-pruning worker
//!          (prune with --workers host:port,... to shard layer solves;
//!           --status-addr exposes live progress over TCP; a coordinator
//!           started with --register-addr accepts `worker --register`
//!           joins mid-run)
//!   info                                      artifact + model inventory
//!   smoke  <file.hlo.txt>                     runtime smoke test
//!
//! Observability: every TCP endpoint (serve front-end, worker port,
//! `--status-addr`) answers `GET /metrics` with the process-global
//! Prometheus exposition from `alps::obs`; `--trace-out PATH` (prune,
//! serve) streams spans/events as JSONL.

use alps::config::{ModelConfig, SparsityTarget};
use alps::coordinator::{ShardedConfig, ShardedEngine};
use alps::data::{sample_windows, synthetic_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::{Model, Weights};
use alps::pruning::session::single_layer_problem;
use alps::pruning::{
    Engine as SolveEngine, HloEngine, MethodSpec, NativeEngine, PruneSession, StatusBoard,
    StatusServer, Worker, WorkerConfig,
};
use alps::runtime::{artifact, Runtime};
use alps::serve::tcp::{fmt_tokens, parse_prompt};
use alps::serve::{Batcher, Engine, SamplingParams, TcpConfig};
use alps::util::table::{fmt_sig, Table};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Minimal flag parser: `--key value` / `--key=value` pairs plus
/// positional args. A `--key` followed by another `--flag` (or nothing)
/// is boolean; values that themselves start with `--` must use the
/// `--key=value` form.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn artifacts_dir() -> PathBuf {
    artifact::default_dir()
}

fn load_model(args: &Args) -> Result<Model> {
    let name = args.get("model", "alps-tiny");
    let dir = artifacts_dir();
    let mut model = Model::load(&dir, &name)
        .with_context(|| format!("loading model '{name}' from {dir:?}"))?;
    if args.has("weights") {
        let w = Weights::load(&PathBuf::from(args.get("weights", "")))?;
        model.weights = w;
    }
    Ok(model)
}

fn load_calib(model: &Model, n: usize) -> Result<Vec<Vec<u16>>> {
    let corpus = Corpus::load(&artifacts_dir().join("corpus.bin"))?;
    let train = corpus.split("train")?;
    Ok(sample_windows(train, n, model.cfg.seq_len, 0xCA11B))
}

/// Apply per-method hyperparameter flags to the spec; rejects knobs that
/// don't belong to the chosen method.
fn apply_method_flags(spec: &mut MethodSpec, args: &Args) -> Result<()> {
    const KNOBS: [&str; 6] =
        ["rho0", "admm-iters", "pcg-iters", "sgpt-block", "sgpt-damp", "dsnot-cycles"];
    let mut consumed: Vec<&str> = Vec::new();
    match spec {
        MethodSpec::Alps(cfg) | MethodSpec::AlpsStructured(cfg) => {
            if args.has("rho0") {
                cfg.rho0 = args.get("rho0", "").parse().context("--rho0")?;
                consumed.push("rho0");
            }
            if args.has("admm-iters") {
                cfg.max_iters = args.get("admm-iters", "").parse().context("--admm-iters")?;
                consumed.push("admm-iters");
            }
            if args.has("pcg-iters") {
                cfg.pcg_iters = args.get("pcg-iters", "").parse().context("--pcg-iters")?;
                consumed.push("pcg-iters");
            }
        }
        MethodSpec::SparseGpt(cfg) => {
            if args.has("sgpt-block") {
                cfg.block_size = args.get("sgpt-block", "").parse().context("--sgpt-block")?;
                consumed.push("sgpt-block");
            }
            if args.has("sgpt-damp") {
                cfg.percdamp = args.get("sgpt-damp", "").parse().context("--sgpt-damp")?;
                consumed.push("sgpt-damp");
            }
        }
        MethodSpec::DsNoT(cfg) => {
            if args.has("dsnot-cycles") {
                cfg.max_cycles =
                    args.get("dsnot-cycles", "").parse().context("--dsnot-cycles")?;
                consumed.push("dsnot-cycles");
            }
        }
        MethodSpec::Magnitude | MethodSpec::Wanda => {}
    }
    for knob in KNOBS {
        if args.has(knob) && !consumed.contains(&knob) {
            bail!("--{knob} does not apply to method '{}'", spec.label());
        }
    }
    Ok(())
}

/// `--trace-out PATH`: stream [`alps::obs`] spans and events as JSONL to
/// `PATH` for the lifetime of the process (one sink per process; the
/// records carry seconds since process start, so lines merge cleanly).
fn install_trace(args: &Args) -> Result<()> {
    if !args.has("trace-out") {
        return Ok(());
    }
    let path = args.get("trace-out", "");
    if path.is_empty() || path == "true" {
        bail!("--trace-out requires a file path (e.g. --trace-out=trace.jsonl)");
    }
    alps::obs::trace::install_sink(&path).with_context(|| format!("opening trace sink {path}"))?;
    println!("tracing spans/events to {path} (JSONL)");
    Ok(())
}

fn cmd_prune(args: &Args) -> Result<()> {
    install_trace(args)?;
    let mut model = if args.has("random") {
        // synthetic weights + calibration: exercises the full pipeline
        // (and checkpoint/resume) without built artifacts
        let name = args.get("model", "alps-tiny");
        let seed = args.get("seed", "41253").parse::<u64>().context("--seed")?;
        Model::random(ModelConfig::preset(&name)?, seed)?
    } else {
        load_model(args)?
    };
    let target = SparsityTarget::parse(&args.get("sparsity", "0.7"))?;
    let mut spec = MethodSpec::parse(&args.get("method", "alps"))?;
    apply_method_flags(&mut spec, args)?;
    let n_calib = args.get("calib", "32").parse::<usize>()?;
    let calib = if args.has("random") {
        synthetic_windows(n_calib, model.cfg.seq_len, model.cfg.vocab, 0xCA11B)
    } else {
        load_calib(&model, n_calib)?
    };

    println!(
        "pruning {} ({} params) to {} with {}",
        model.cfg.name,
        model.weights.total_params(),
        target.label(),
        spec.label()
    );
    let rt = if args.get("engine", "native") == "hlo" {
        Some(Runtime::new(&artifacts_dir())?)
    } else {
        None
    };
    let mut builder = PruneSession::builder()
        .calib(calib)
        .target(target)
        .verbose(!args.has("quiet"));
    if args.has("checkpoint-dir") {
        let dir = args.get("checkpoint-dir", "");
        // a bare `--checkpoint-dir` followed by another flag parses as the
        // boolean value "true" — almost certainly a forgotten path
        if dir.is_empty() || dir == "true" {
            bail!("--checkpoint-dir requires a path (e.g. --checkpoint-dir=ck)");
        }
        builder = builder.checkpoint_dir(dir);
    }
    if args.has("resume") {
        builder = builder.resume(true);
    }
    if args.has("stop-after") {
        builder =
            builder.stop_after(args.get("stop-after", "").parse().context("--stop-after")?);
    }

    // the status board outlives the engine selection: a sharded engine
    // feeds worker heartbeats into the same board the endpoint serves
    let board: Option<std::sync::Arc<StatusBoard>> = if args.has("status-addr") {
        Some(std::sync::Arc::new(StatusBoard::new()))
    } else {
        None
    };

    // where layers get solved: a remote worker pool, the HLO runtime, or
    // the in-process native engine
    let workers_flag = args.get("workers", "");
    if args.has("register-addr") && (workers_flag.is_empty() || workers_flag == "true") {
        bail!("--register-addr extends a sharded pool: it requires --workers host:port[,...]");
    }
    let engine: Box<dyn SolveEngine + '_> = if !workers_flag.is_empty() && workers_flag != "true" {
        if rt.is_some() {
            bail!("--workers cannot combine with --engine hlo");
        }
        // pool tuning: long solves need a bigger idle allowance, flaky
        // links a bigger retry budget — both reachable without recompiling
        let mut shard_cfg = ShardedConfig::default();
        if args.has("shard-idle") {
            shard_cfg.idle_timeout = std::time::Duration::from_secs(
                args.get("shard-idle", "").parse().context("--shard-idle (seconds)")?,
            );
        }
        if args.has("shard-heartbeat") {
            let grace: u64 = args
                .get("shard-heartbeat", "")
                .parse()
                .context("--shard-heartbeat (seconds)")?;
            // workers beat every --heartbeat-secs (default 2, capped at
            // 5); the 15s floor keeps >= 3 beat intervals inside every
            // legal grace, so healthy workers can never be declared dead
            if grace < 15 {
                bail!(
                    "--shard-heartbeat must be >= 15 seconds: workers send a \
                     keepalive every `--heartbeat-secs` (default 2, max 5), and \
                     the grace must cover several beat intervals"
                );
            }
            shard_cfg.heartbeat_grace = std::time::Duration::from_secs(grace);
        }
        if args.has("shard-attempts") {
            shard_cfg.max_attempts =
                args.get("shard-attempts", "").parse().context("--shard-attempts")?;
        }
        if args.has("shard-outstanding") {
            shard_cfg.max_outstanding =
                args.get("shard-outstanding", "").parse().context("--shard-outstanding")?;
        }
        if args.has("ship-activations") {
            // worker-side gram: ship X [n, n_in] once per layer instead of
            // the O(n_in^2) gram — a large wire saving for wide layers
            shard_cfg.ship_activations = true;
        }
        let workers: Vec<String> = workers_flag
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        let mut eng = ShardedEngine::with_config(spec, workers, shard_cfg)?;
        if let Some(board) = &board {
            eng.set_status_board(board.clone());
        }
        if args.has("register-addr") {
            let reg = args.get("register-addr", "");
            if reg.is_empty() || reg == "true" {
                bail!(
                    "--register-addr requires host:port (e.g. --register-addr=127.0.0.1:7880)"
                );
            }
            let bound = eng.listen_for_registrations(&reg)?;
            println!(
                "registration endpoint on {bound} — workers can join mid-run with \
                 `alps worker --register {bound}`"
            );
        }
        println!(
            "sharded across {} worker(s): {workers_flag}{}",
            eng.workers().len(),
            if args.has("ship-activations") { " (shipping activations)" } else { "" }
        );
        Box::new(eng)
    } else if args.has("workers") {
        bail!("--workers requires host:port[,host:port...]");
    } else if let Some(rt) = &rt {
        let MethodSpec::Alps(cfg) = spec else {
            bail!("--engine hlo only supports --method alps");
        };
        Box::new(HloEngine::new(rt, cfg))
    } else {
        Box::new(NativeEngine::new(spec))
    };
    let builder = builder.engine(engine);

    let report = if let Some(board) = &board {
        let addr = args.get("status-addr", "");
        if addr.is_empty() || addr == "true" {
            bail!("--status-addr requires host:port (e.g. --status-addr=127.0.0.1:7878)");
        }
        let listener = std::net::TcpListener::bind(&addr)
            .with_context(|| format!("binding status endpoint {addr}"))?;
        println!("status endpoint on {addr} (GET /status, or a `status` line)");
        let status = StatusServer::new();
        // stop the endpoint on unwind too: scope joins the server thread,
        // so a panicking run must not leave it accepting forever
        struct StopOnDrop<'a>(&'a StatusServer);
        impl Drop for StopOnDrop<'_> {
            fn drop(&mut self) {
                self.0.request_shutdown();
            }
        }
        std::thread::scope(|s| {
            let _stop = StopOnDrop(&status);
            let srv = s.spawn(|| status.serve(listener, board));
            let r = builder.observer(|ev| board.observe(ev)).run(&mut model);
            status.request_shutdown();
            if let Err(e) = srv.join().expect("status server panicked") {
                eprintln!("status endpoint error: {e}");
            }
            r
        })?
    } else {
        builder.run(&mut model)?
    };
    if let Some(rt) = &rt {
        println!("(hlo engine: {} artifact executions)", rt.total_execs());
    }
    println!("{}", report.summary());

    let out = args.get("out", "");
    if !out.is_empty() {
        model.weights.save(&PathBuf::from(&out))?;
        println!("wrote pruned weights to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let corpus = Corpus::load(&artifacts_dir().join("corpus.bin"))?;
    let n_items = args.get("items", "50").parse::<usize>()?;

    let mut t = Table::new(&["dataset", "metric", "value"]);
    for split in Corpus::eval_split_names() {
        let ids = corpus.split(split)?;
        let ppl = perplexity(&model, ids)?;
        t.row(&[split.to_string(), "ppl".into(), fmt_sig(ppl)]);
    }
    let test_ids = corpus.split("wikitext2-like")?;
    for task in tasks::standard_tasks(test_ids, n_items, model.cfg.seq_len, model.cfg.vocab, 7) {
        let acc = zero_shot_accuracy(&model, &task)?;
        t.row(&[task.name.to_string(), "acc%".into(), format!("{:.2}", acc * 100.0)]);
    }
    let names = model.prunable_names();
    println!(
        "model {} — prunable sparsity {:.3}",
        model.cfg.name,
        model.weights.sparsity_of(&names)
    );
    t.print();
    Ok(())
}

fn cmd_layer(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let layer = args.get("layer", "mlp.w2");
    let block = args.get("block", "0").parse::<usize>()?;
    let calib = load_calib(&model, args.get("calib", "32").parse()?)?;
    let p = single_layer_problem(&model, &calib, block, &layer)?;
    let target = SparsityTarget::parse(&args.get("sparsity", "0.7"))?;

    println!(
        "layer blocks.{block}.{layer} ({}x{}), target {}",
        p.n_in(),
        p.n_out(),
        target.label()
    );
    let mut t = Table::new(&["method", "rel-error", "nnz", "secs"]);
    let specs = if args.get("methods", "all") == "all" {
        MethodSpec::all()
    } else {
        args.get("methods", "alps")
            .split(',')
            .map(MethodSpec::parse)
            .collect::<Result<Vec<_>>>()?
    };
    for spec in specs {
        let timer = alps::util::Timer::start();
        let w = spec.prune(&p, target)?;
        let secs = timer.elapsed_secs();
        t.row(&[
            spec.label().to_string(),
            fmt_sig(p.rel_error(&w)),
            w.nnz().to_string(),
            format!("{secs:.2}"),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    install_trace(args)?;
    let name = args.get("model", "alps-tiny");
    let model = if args.has("random") {
        // synthetic weights: lets the server run without built artifacts;
        // --weights still applies so smoke tests can serve a pruned
        // checkpoint without shipping the full artifact set
        let mut m = Model::random(ModelConfig::preset(&name)?, 0xA125)?;
        if args.has("weights") {
            m.weights = Weights::load(&PathBuf::from(args.get("weights", "")))?;
        }
        m
    } else {
        load_model(args)?
    };
    let engine = build_engine(&model, args)?;
    let stop_token = match args.flags.get("stop") {
        Some(s) => Some(s.parse::<u16>().context("--stop token id")?),
        None => None,
    };
    let params = SamplingParams {
        max_new_tokens: args.get("max-new", "32").parse().context("--max-new")?,
        temperature: args.get("temperature", "0").parse().context("--temperature")?,
        top_k: args.get("top-k", "0").parse().context("--top-k")?,
        stop_token,
    };
    let cfg = TcpConfig {
        max_batch: args.get("max-batch", "8").parse().context("--max-batch")?,
        max_conns: args.get("max-conns", "64").parse().context("--max-conns")?,
        max_line_bytes: args.get("max-line", "65536").parse().context("--max-line")?,
    };
    println!(
        "serving {} [{}] — vocab {}, ctx {}, max batch {}, threads {}",
        model.cfg.name,
        engine.label(),
        model.cfg.vocab,
        model.cfg.seq_len,
        cfg.max_batch,
        alps::linalg::matmul::num_threads(),
    );
    if args.has("stdin") {
        serve_stdin(&engine, &params, cfg.max_batch)
    } else {
        serve_tcp(&engine, &params, &cfg, &args.get("addr", "127.0.0.1:7878"))
    }
}

/// Pick the serving weight backend from
/// `--format dense|csr|nm[:N:M]|int8` (default dense; the older
/// `--sparse` flag stays as a csr alias). Bare `nm` means 2:4;
/// `nm:4:8` etc. selects another pattern; `int8` quantizes every
/// prunable matrix at load.
fn build_engine<'m>(model: &'m Model, args: &Args) -> Result<Engine<'m>> {
    let format = if args.has("format") {
        args.get("format", "dense")
    } else if args.has("sparse") {
        "csr".to_string()
    } else {
        "dense".to_string()
    };
    match format.as_str() {
        "dense" => Engine::dense(model),
        "csr" | "sparse" => Engine::sparse(model),
        "nm" => Engine::nm(model, 2, 4),
        "int8" => Engine::int8(model),
        f => match f.strip_prefix("nm:") {
            Some(pat) => match SparsityTarget::parse(pat)? {
                SparsityTarget::NM { n, m } => Engine::nm(model, n, m),
                SparsityTarget::Unstructured(_) => {
                    bail!("--format nm:<pattern> needs an N:M pattern, got '{pat}'")
                }
            },
            None => bail!("unknown --format '{f}' (expected dense|csr|nm[:N:M]|int8)"),
        },
    }
}

/// Batch every prompt line from stdin through the continuous batcher,
/// print `<id>: <tokens>` lines plus the metrics table.
fn serve_stdin(engine: &Engine, params: &SamplingParams, max_batch: usize) -> Result<()> {
    let mut batcher = Batcher::new(engine, max_batch);
    for line in std::io::stdin().lines() {
        let line = line.context("reading stdin")?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_prompt(line) {
            Ok(p) => {
                batcher.submit(p, params.clone());
            }
            Err(e) => eprintln!("skipping line: {e}"),
        }
    }
    let mut responses = batcher.run_to_completion()?;
    responses.sort_by_key(|r| r.id);
    for r in responses {
        match r.error {
            Some(e) => println!("{}: ERR {e}", r.id),
            None => println!("{}: {}", r.id, fmt_tokens(&r.tokens)),
        }
    }
    println!("{}", batcher.metrics.render());
    Ok(())
}

/// Threaded multi-connection line protocol over TCP — see
/// `alps::serve::tcp` for the protocol and threading model. Runs until a
/// client sends `shutdown`, then prints the final metrics report.
fn serve_tcp(
    engine: &Engine,
    params: &SamplingParams,
    cfg: &TcpConfig,
    addr: &str,
) -> Result<()> {
    let listener =
        std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "listening on {addr} — up to {} connections; prompt lines ack `queued <id>`, \
         blank line or `run` waits for results, `stats` for metrics, `shutdown` stops; \
         GET /healthz for status",
        cfg.max_conns
    );
    let report = alps::serve::tcp::serve(listener, engine, params, cfg)?;
    println!("{report}");
    Ok(())
}

/// Host the native layer solvers behind the pruning frame protocol so a
/// coordinator (`alps prune --workers ...`) can shard blocks over here.
/// Stateless: each request carries its method spec and target, so one
/// worker serves any mix of runs. Runs until killed. With `--register`,
/// a sidecar thread dials the coordinator's registration endpoint so
/// this worker joins an already-running sharded pool.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7979");
    let heartbeat_secs = args
        .get("heartbeat-secs", "2")
        .parse::<f64>()
        .context("--heartbeat-secs")?;
    // coordinators reroute after `--shard-heartbeat` (default 30 s, CLI
    // floor 15 s) of silence; capping beats at 5 s keeps >= 3 intervals
    // inside every legal grace, so the two knobs can never cross
    if !(heartbeat_secs > 0.0 && heartbeat_secs <= 5.0) {
        bail!(
            "--heartbeat-secs must be in (0, 5]: coordinators treat silence \
             past their --shard-heartbeat grace (>= 15s, default 30s) as a \
             dead worker, so beats must stay comfortably inside that window"
        );
    }
    let cfg = WorkerConfig {
        max_conns: args.get("max-conns", "8").parse().context("--max-conns")?,
        // clamp before shifting: a huge MiB value must not wrap the
        // byte count around to a tiny (or zero) frame cap
        max_frame_bytes: args
            .get("max-frame-mb", "1024")
            .parse::<usize>()
            .context("--max-frame-mb")?
            .clamp(1, usize::MAX >> 20)
            << 20,
        // keep well under the coordinator's heartbeat grace (default 30s)
        heartbeat_every: std::time::Duration::from_secs_f64(heartbeat_secs),
    };
    let listener = std::net::TcpListener::bind(&addr)
        .with_context(|| format!("binding worker address {addr}"))?;
    println!(
        "worker on {addr} — up to {} coordinator connections, frames to {} MiB, \
         heartbeat every {:.1}s while solving; point a coordinator at it with \
         `alps prune --workers {addr}`",
        cfg.max_conns,
        cfg.max_frame_bytes >> 20,
        cfg.heartbeat_every.as_secs_f64(),
    );
    let worker = Worker::new(cfg);
    if args.has("register") {
        let coord = args.get("register", "");
        if coord.is_empty() || coord == "true" {
            bail!(
                "--register requires the coordinator's registration endpoint \
                 (host:port from its --register-addr)"
            );
        }
        // advertise the *bound* address, not the flag: `--addr host:0`
        // must announce the kernel-assigned port
        let advertise = listener
            .local_addr()
            .context("reading bound worker address")?
            .to_string();
        std::thread::scope(|s| -> Result<()> {
            let shutdown = worker.shutdown_flag();
            let dialer = s.spawn(move || {
                let r = alps::pruning::register_with_coordinator(&coord, &advertise, shutdown);
                if r.is_ok() {
                    println!("registered with coordinator {coord} as {advertise}");
                }
                r
            });
            let served = worker.serve(listener);
            match dialer.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("registration failed: {e}"),
                Err(_) => eprintln!("registration thread panicked"),
            }
            served
        })?;
    } else {
        worker.serve(listener)?;
    }
    println!("worker done — {} layers solved", worker.layers_solved());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = artifacts_dir();
    println!("artifacts dir: {dir:?}");
    match alps::runtime::Manifest::load(&dir) {
        Ok(m) => {
            let mut kinds: HashMap<String, usize> = HashMap::new();
            for a in m.artifacts.values() {
                *kinds.entry(a.kind.clone()).or_insert(0) += 1;
            }
            println!("{} artifacts:", m.artifacts.len());
            let mut ks: Vec<_> = kinds.into_iter().collect();
            ks.sort();
            for (k, n) in ks {
                println!("  {k}: {n}");
            }
        }
        Err(e) => println!("no manifest: {e}"),
    }
    for preset in ["alps-tiny", "alps-small", "alps-base"] {
        match Model::load(&dir, preset) {
            Ok(m) => println!(
                "model {preset}: {} params, {} blocks",
                m.weights.total_params(),
                m.cfg.n_layers
            ),
            Err(_) => println!("model {preset}: not built (run `make artifacts`)"),
        }
    }
    match Corpus::load(&dir.join("corpus.bin")) {
        Ok(c) => println!(
            "corpus: vocab {}, splits {:?}",
            c.vocab.len(),
            c.splits.iter().map(|(k, v)| format!("{k}:{}", v.len())).collect::<Vec<_>>()
        ),
        Err(_) => println!("corpus: not built"),
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "artifacts/smoke.hlo.txt".to_string());
    let out = alps::runtime::smoke::run_hlo_f32(
        &path,
        &[
            ((0..24).map(|i| i as f32).collect(), vec![4, 6]),
            ((0..24).map(|i| (23 - i) as f32 * 0.5).collect(), vec![4, 6]),
        ],
        Some(7),
    )?;
    for (i, v) in out.iter().enumerate() {
        let head: Vec<f32> = v.iter().take(8).cloned().collect();
        println!("out[{i}] len={} head={head:?}", v.len());
    }
    println!("smoke OK");
    Ok(())
}

fn usage() {
    println!(
        "alps — ADMM-based one-shot LLM pruning (NeurIPS 2024 reproduction)\n\
         usage: alps <prune|eval|layer|serve|worker|info|smoke> [flags]\n\
           prune --model alps-base --sparsity 0.7|2:4 --method alps|mp|wanda|sparsegpt|dsnot\n\
                 [--engine native|hlo] [--calib 32] [--out pruned.bin] [--quiet]\n\
                 [--checkpoint-dir ck] [--resume] [--stop-after N] [--random] [--seed N]\n\
                 [--workers host:port,host:port] [--ship-activations]\n\
                 [--register-addr 127.0.0.1:7880 (accept mid-run worker joins)]\n\
                 [--status-addr 127.0.0.1:7878] [--shard-idle SECS] [--shard-heartbeat SECS]\n\
                 [--shard-attempts N] [--shard-outstanding N] [--trace-out trace.jsonl]\n\
                 [--rho0 F] [--admm-iters N] [--pcg-iters N]   (alps)\n\
                 [--sgpt-block N] [--sgpt-damp F]              (sparsegpt)\n\
                 [--dsnot-cycles N]                            (dsnot)\n\
           eval  --model alps-base [--weights pruned.bin] [--items 50]\n\
           layer --model alps-base --block 0 --layer mlp.w2 --sparsity 0.7 [--methods all]\n\
           serve --model alps-base [--weights pruned.bin] [--random]\n\
                 [--format dense|csr|nm[:N:M]|int8] [--sparse (= --format csr)]\n\
                 [--addr 127.0.0.1:7878 | --stdin] [--max-batch 8] [--max-conns 64]\n\
                 [--max-line 65536] [--max-new 32] [--temperature 0] [--top-k 0] [--stop id]\n\
                 [--trace-out trace.jsonl]\n\
           worker [--addr 127.0.0.1:7979] [--max-conns 8] [--max-frame-mb 1024]\n\
                 [--heartbeat-secs 2] [--register COORD_HOST:PORT]\n\
                 hosts the native layer solvers for `prune --workers`;\n\
                 --register dials a coordinator's --register-addr to join mid-run\n\
           info\n\
           smoke [file.hlo.txt]"
    );
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "prune" => cmd_prune(&args),
        "eval" => cmd_eval(&args),
        "layer" => cmd_layer(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "info" => cmd_info(),
        "smoke" => cmd_smoke(&args),
        _ => {
            usage();
            bail!("unknown command '{cmd}'");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn args_key_value_pairs_and_positionals() {
        let a = Args::parse(&argv(&["--model", "alps-tiny", "file.hlo", "--quiet"]));
        assert_eq!(a.get("model", "x"), "alps-tiny");
        assert!(a.has("quiet"));
        assert_eq!(a.get("quiet", ""), "true");
        assert_eq!(a.positional, vec!["file.hlo"]);
        assert_eq!(a.get("missing", "dflt"), "dflt");
    }

    #[test]
    fn args_equals_syntax() {
        let a = Args::parse(&argv(&["--sparsity=0.7", "--method=alps"]));
        assert_eq!(a.get("sparsity", ""), "0.7");
        assert_eq!(a.get("method", ""), "alps");
    }

    #[test]
    fn args_equals_allows_dashed_values() {
        // regression: a space-separated value starting with `--` used to be
        // swallowed as a boolean flag; `--key=value` must carry it intact
        let a = Args::parse(&argv(&["--stop=--weird", "--name=--x=y"]));
        assert_eq!(a.get("stop", ""), "--weird");
        // only the first '=' splits
        assert_eq!(a.get("name", ""), "--x=y");
    }

    #[test]
    fn args_flag_before_flag_is_boolean() {
        let a = Args::parse(&argv(&["--resume", "--model", "alps-tiny"]));
        assert!(a.has("resume"));
        assert_eq!(a.get("model", ""), "alps-tiny");
    }

    #[test]
    fn args_empty_equals_value() {
        let a = Args::parse(&argv(&["--out="]));
        assert!(a.has("out"));
        assert_eq!(a.get("out", "x"), "");
    }

    #[test]
    fn method_flags_reach_the_spec() {
        let a = Args::parse(&argv(&["--rho0", "0.5", "--admm-iters", "33"]));
        let mut spec = MethodSpec::parse("alps").unwrap();
        apply_method_flags(&mut spec, &a).unwrap();
        match spec {
            MethodSpec::Alps(cfg) => {
                assert_eq!(cfg.rho0, 0.5);
                assert_eq!(cfg.max_iters, 33);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn method_flags_rejected_for_wrong_method() {
        let a = Args::parse(&argv(&["--rho0", "0.5"]));
        let mut spec = MethodSpec::parse("mp").unwrap();
        let err = apply_method_flags(&mut spec, &a).unwrap_err().to_string();
        assert!(err.contains("--rho0"), "{err}");
        assert!(err.contains("'mp'"), "{err}");
    }

    #[test]
    fn unknown_method_is_an_early_error() {
        // regression for the old validate-then-rediscard path: the spec
        // parse is the single point of failure for bad method names
        let err = MethodSpec::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown method"), "{err}");
    }
}
