//! Typed configuration: model presets, pruning hyperparameters, run setup.

pub mod json;

use anyhow::{bail, Context, Result};
use json::Json;
use std::path::Path;

/// Transformer architecture config (mirrors python/compile/model.py PRESETS).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let c = match name {
            "alps-tiny" => ModelConfig {
                name: name.into(), d_model: 128, d_ff: 512, n_layers: 2,
                n_heads: 4, vocab: 512, seq_len: 128,
            },
            "alps-small" => ModelConfig {
                name: name.into(), d_model: 192, d_ff: 768, n_layers: 4,
                n_heads: 6, vocab: 512, seq_len: 128,
            },
            "alps-base" => ModelConfig {
                name: name.into(), d_model: 256, d_ff: 1024, n_layers: 6,
                n_heads: 8, vocab: 512, seq_len: 128,
            },
            _ => bail!("unknown model preset '{name}' (alps-tiny/small/base)"),
        };
        Ok(c)
    }

    pub fn from_json_file(path: &Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model config {path:?}"))?;
        let v = Json::parse(&text)?;
        let cfg = ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            d_model: v.get("d_model")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model == 0 || self.n_layers == 0 || self.vocab == 0 {
            bail!("model config has zero-sized field: {self:?}");
        }
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        Ok(())
    }

    /// Distinct prunable (n_in, n_out) shapes.
    pub fn prunable_shapes(&self) -> Vec<(usize, usize)> {
        vec![
            (self.d_model, self.d_model),
            (self.d_model, self.d_ff),
            (self.d_ff, self.d_model),
        ]
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let ff = self.d_ff;
        self.vocab * d
            + self.seq_len * d
            + self.n_layers * (4 * d * d + 2 * d * ff + 4 * d)
            + 2 * d
    }
}

/// Sparsity target: unstructured fraction or an N:M pattern.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsityTarget {
    /// Fraction of weights to REMOVE (0.7 => keep 30%).
    Unstructured(f64),
    /// Keep n of every m consecutive weights (2:4 => NM { n: 2, m: 4 }).
    NM { n: usize, m: usize },
}

impl SparsityTarget {
    /// Parse "0.7" or "2:4".
    pub fn parse(s: &str) -> Result<SparsityTarget> {
        if let Some((a, b)) = s.split_once(':') {
            let n: usize = a.trim().parse().context("N in N:M")?;
            let m: usize = b.trim().parse().context("M in N:M")?;
            if n == 0 || m == 0 || n > m {
                bail!("invalid N:M pattern {s}");
            }
            Ok(SparsityTarget::NM { n, m })
        } else {
            let f: f64 = s.trim().parse().context("sparsity fraction")?;
            if !(0.0..1.0).contains(&f) {
                bail!("sparsity must be in [0, 1), got {f}");
            }
            Ok(SparsityTarget::Unstructured(f))
        }
    }

    /// Number of weights kept for a (n_in x n_out) layer.
    pub fn keep_count(&self, n_in: usize, n_out: usize) -> usize {
        match self {
            SparsityTarget::Unstructured(s) => {
                (((1.0 - s) * (n_in * n_out) as f64).floor() as usize).max(1)
            }
            SparsityTarget::NM { n, m } => n_in * n_out * n / m,
        }
    }

    /// The removed fraction this target corresponds to.
    pub fn sparsity_fraction(&self) -> f64 {
        match self {
            SparsityTarget::Unstructured(s) => *s,
            SparsityTarget::NM { n, m } => 1.0 - (*n as f64) / (*m as f64),
        }
    }

    pub fn label(&self) -> String {
        match self {
            SparsityTarget::Unstructured(s) => format!("{s:.2}"),
            SparsityTarget::NM { n, m } => format!("{n}:{m}"),
        }
    }
}

/// ALPS (ADMM + PCG) hyperparameters — defaults are the paper's B.1 values.
#[derive(Clone, Debug, PartialEq)]
pub struct AlpsConfig {
    /// Initial penalty rho_0 (paper: 0.1).
    pub rho0: f32,
    /// Update rho every `update_every` iterations (paper: 3).
    pub update_every: usize,
    /// rho multipliers for the three support-change bands (eq. 28).
    pub rho_factors: (f32, f32, f32),
    /// Support-change thresholds relative to k (eq. 28: 0.1k, 0.005k, 1).
    pub support_bands: (f64, f64),
    /// Hard cap on ADMM iterations.
    pub max_iters: usize,
    /// PCG refinement iterations after support stabilization (paper: 10).
    pub pcg_iters: usize,
    /// Apply the B.1 diagonal (Jacobi) scaling preprocessing.
    pub diag_scaling: bool,
    /// Ridge damping added to diag(H) as a fraction of mean diag (like
    /// SparseGPT's percdamp) to keep degenerate grams invertible.
    pub damp: f32,
}

impl Default for AlpsConfig {
    fn default() -> Self {
        AlpsConfig {
            rho0: 0.1,
            update_every: 3,
            rho_factors: (1.3, 1.2, 1.1),
            support_bands: (0.1, 0.005),
            max_iters: 600,
            pcg_iters: 10,
            diag_scaling: true,
            damp: 1e-2,
        }
    }
}

/// SparseGPT (Frantar & Alistarh 2023) hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGptConfig {
    /// Mask-selection block size (paper: 128; scaled for our layer sizes).
    pub block_size: usize,
    /// Ridge damping fraction of mean diag (paper's percdamp: 0.01).
    pub percdamp: f32,
}

impl Default for SparseGptConfig {
    fn default() -> Self {
        SparseGptConfig { block_size: 64, percdamp: 0.01 }
    }
}

/// DSnoT (Zhang et al. 2023) hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct DsNoTConfig {
    /// Maximum grow/prune cycles per column (paper default: 50).
    pub max_cycles: usize,
    /// Stop when the improvement of a swap falls below this.
    pub min_gain: f64,
}

impl Default for DsNoTConfig {
    fn default() -> Self {
        DsNoTConfig { max_cycles: 50, min_gain: 1e-9 }
    }
}

/// Calibration setup (mirrors python/compile/aot.py CALIB_* constants).
#[derive(Clone, Debug)]
pub struct CalibConfig {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { n_seqs: 32, seq_len: 128, seed: 0xCA11B }
    }
}

impl CalibConfig {
    pub fn rows(&self) -> usize {
        self.n_seqs * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        for name in ["alps-tiny", "alps-small", "alps-base"] {
            let c = ModelConfig::preset(name).unwrap();
            c.validate().unwrap();
            assert_eq!(c.vocab, 512);
        }
        assert!(ModelConfig::preset("nope").is_err());
    }

    #[test]
    fn preset_param_counts_reasonable() {
        let tiny = ModelConfig::preset("alps-tiny").unwrap();
        let base = ModelConfig::preset("alps-base").unwrap();
        assert!(tiny.n_params() < base.n_params());
        assert!(base.n_params() > 4_000_000);
    }

    #[test]
    fn sparsity_parse_unstructured() {
        let t = SparsityTarget::parse("0.7").unwrap();
        assert_eq!(t, SparsityTarget::Unstructured(0.7));
        assert_eq!(t.keep_count(10, 10), 30);
        assert_eq!(t.label(), "0.70");
    }

    #[test]
    fn sparsity_parse_nm() {
        let t = SparsityTarget::parse("2:4").unwrap();
        assert_eq!(t, SparsityTarget::NM { n: 2, m: 4 });
        assert_eq!(t.keep_count(8, 4), 16);
        assert!((t.sparsity_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(t.label(), "2:4");
    }

    #[test]
    fn sparsity_parse_rejects_bad() {
        assert!(SparsityTarget::parse("1.5").is_err());
        assert!(SparsityTarget::parse("-0.1").is_err());
        assert!(SparsityTarget::parse("4:2").is_err());
        assert!(SparsityTarget::parse("0:4").is_err());
        assert!(SparsityTarget::parse("abc").is_err());
    }

    #[test]
    fn keep_count_at_least_one() {
        let t = SparsityTarget::Unstructured(0.999);
        assert!(t.keep_count(10, 10) >= 1);
    }

    #[test]
    fn model_json_roundtrip() {
        let dir = std::env::temp_dir().join("alps_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.json");
        std::fs::write(
            &p,
            r#"{"name": "x", "d_model": 64, "d_ff": 128, "n_layers": 2,
               "n_heads": 4, "vocab": 100, "seq_len": 32}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json_file(&p).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.prunable_shapes(), vec![(64, 64), (64, 128), (128, 64)]);
    }

    #[test]
    fn validate_rejects_bad_heads() {
        let mut c = ModelConfig::preset("alps-tiny").unwrap();
        c.n_heads = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn alps_defaults_match_paper() {
        let a = AlpsConfig::default();
        assert_eq!(a.rho0, 0.1);
        assert_eq!(a.update_every, 3);
        assert_eq!(a.rho_factors, (1.3, 1.2, 1.1));
        assert_eq!(a.pcg_iters, 10);
    }

    #[test]
    fn calib_rows() {
        assert_eq!(CalibConfig::default().rows(), 32 * 128);
    }

    #[test]
    fn method_config_defaults() {
        let sg = SparseGptConfig::default();
        assert_eq!(sg.block_size, 64);
        assert_eq!(sg.percdamp, 0.01);
        let ds = DsNoTConfig::default();
        assert_eq!(ds.max_cycles, 50);
        assert!(ds.min_gain > 0.0);
        // configs are comparable (MethodSpec derives PartialEq off these)
        assert_eq!(sg, SparseGptConfig::default());
        assert_ne!(ds, DsNoTConfig { max_cycles: 0, ..Default::default() });
    }
}
