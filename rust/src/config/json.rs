//! Minimal JSON parser (serde is unavailable offline) — enough for the
//! artifact manifest and model/run config files this repo writes itself.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    /// Object field lookup with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' at offset {}, got '{}'", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at offset {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    e => bail!("bad escape '\\{}'", e as char),
                },
                b => {
                    // collect the UTF-8 sequence starting at b
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    for _ in 1..len {
                        self.bump()?;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(out)),
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert_eq!(*v.get("d").unwrap(), Json::Null);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" \\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \\ A");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n  \"x\" :\t[ 1 ,2 ]\r\n} ").unwrap();
        assert_eq!(v.get("x").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("42 garbage").is_err());
        assert!(Json::parse(r#"{"a": }"#).is_err());
    }

    #[test]
    fn as_usize_validation() {
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Json::parse("7.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn missing_key_error_message() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = v.get("b").unwrap_err().to_string();
        assert!(err.contains("'b'"), "{err}");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
