//! Data pipeline: corpus loading (build-time artifact), calibration
//! sampling, eval-window construction, and synthetic zero-shot tasks.

pub mod corpus;
pub mod tasks;

pub use corpus::Corpus;

use crate::util::Rng;

/// Sample `n` random windows of `len` tokens from a token stream (the
/// paper's "128 segments of 2048 tokens randomly selected from C4").
pub fn sample_windows(ids: &[u16], n: usize, len: usize, seed: u64) -> Vec<Vec<u16>> {
    assert!(ids.len() > len, "stream shorter than window");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let start = rng.below(ids.len() - len);
            ids[start..start + len].to_vec()
        })
        .collect()
}

/// Non-overlapping full-stride eval windows (HuggingFace full-stride
/// perplexity convention).
pub fn eval_windows(ids: &[u16], len: usize) -> Vec<Vec<u16>> {
    ids.chunks_exact(len).map(|c| c.to_vec()).collect()
}

/// `n` windows of uniform-random token ids — calibration input for
/// synthetic-model runs (`prune --random`, examples) where no corpus
/// artifact has been built.
pub fn synthetic_windows(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_windows_shape_and_bounds() {
        let ids: Vec<u16> = (0..1000u16).collect();
        let w = sample_windows(&ids, 10, 50, 0);
        assert_eq!(w.len(), 10);
        for win in &w {
            assert_eq!(win.len(), 50);
            // window must be contiguous
            for i in 1..win.len() {
                assert_eq!(win[i], win[i - 1] + 1);
            }
        }
    }

    #[test]
    fn sample_windows_deterministic() {
        let ids: Vec<u16> = (0..500u16).collect();
        assert_eq!(sample_windows(&ids, 5, 20, 7), sample_windows(&ids, 5, 20, 7));
        assert_ne!(sample_windows(&ids, 5, 20, 7), sample_windows(&ids, 5, 20, 8));
    }

    #[test]
    fn eval_windows_full_stride() {
        let ids: Vec<u16> = (0..105u16).collect();
        let w = eval_windows(&ids, 25);
        assert_eq!(w.len(), 4); // 105 / 25 = 4 full windows, tail dropped
        assert_eq!(w[1][0], 25);
    }

    #[test]
    fn synthetic_windows_shape_and_determinism() {
        let w = synthetic_windows(4, 16, 100, 3);
        assert_eq!(w.len(), 4);
        for win in &w {
            assert_eq!(win.len(), 16);
            assert!(win.iter().all(|&t| (t as usize) < 100));
        }
        assert_eq!(w, synthetic_windows(4, 16, 100, 3));
        assert_ne!(w, synthetic_windows(4, 16, 100, 4));
    }
}
