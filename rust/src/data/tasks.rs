//! Synthetic zero-shot benchmark tasks (stand-ins for LAMBADA / PIQA /
//! ARC-Easy / ARC-Challenge; see DESIGN.md §Substitutions).
//!
//! All tasks score candidate continuations by length-normalized sequence
//! log-likelihood — the same decision rule lm-eval-harness uses — so the
//! eval code path matches the paper's; only the item *construction* is
//! synthetic (windows of the held-out corpus with controlled corruptions).

use crate::util::Rng;

/// One multiple-choice item: a shared prefix and candidate continuations.
/// `correct` indexes the true continuation.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prefix: Vec<u16>,
    pub choices: Vec<Vec<u16>>,
    pub correct: usize,
}

/// A named task: a set of items.
pub struct Task {
    pub name: &'static str,
    pub items: Vec<TaskItem>,
}

/// LAMBADA-like: predict the final token of a window. Choices are the true
/// token vs. 3 random vocabulary tokens (final-word prediction as 4-way LL
/// comparison — equivalent to greedy-match on these small vocabs).
pub fn lambada_like(ids: &[u16], n_items: usize, seq: usize, vocab: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n_items);
    while items.len() < n_items {
        let start = rng.below(ids.len() - seq - 1);
        let window = &ids[start..start + seq];
        let prefix = window[..seq - 1].to_vec();
        let truth = vec![window[seq - 1]];
        let mut choices = vec![truth.clone()];
        while choices.len() < 4 {
            let tok = rng.below(vocab) as u16;
            if tok != window[seq - 1] {
                choices.push(vec![tok]);
            }
        }
        let correct = shuffle_choices(&mut choices, 0, &mut rng);
        items.push(TaskItem { prefix, choices, correct });
    }
    Task { name: "lambada-like", items }
}

/// PIQA-like: 2-way choice between the true continuation and a window
/// sampled from elsewhere in the corpus (plausible but wrong).
pub fn piqa_like(ids: &[u16], n_items: usize, prefix_len: usize, cont_len: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed);
    let total = prefix_len + cont_len;
    let mut items = Vec::with_capacity(n_items);
    while items.len() < n_items {
        let start = rng.below(ids.len() - total);
        let prefix = ids[start..start + prefix_len].to_vec();
        let truth = ids[start + prefix_len..start + total].to_vec();
        let alt_start = rng.below(ids.len() - cont_len);
        let alt = ids[alt_start..alt_start + cont_len].to_vec();
        if alt == truth {
            continue;
        }
        let mut choices = vec![truth, alt];
        let correct = shuffle_choices(&mut choices, 0, &mut rng);
        items.push(TaskItem { prefix, choices, correct });
    }
    Task { name: "piqa-like", items }
}

/// ARC-Easy-like: 4-way choice, distractors from distant corpus windows.
pub fn arc_easy_like(ids: &[u16], n_items: usize, prefix_len: usize, cont_len: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed);
    let total = prefix_len + cont_len;
    let mut items = Vec::with_capacity(n_items);
    while items.len() < n_items {
        let start = rng.below(ids.len() - total);
        let prefix = ids[start..start + prefix_len].to_vec();
        let truth = ids[start + prefix_len..start + total].to_vec();
        let mut choices = vec![truth.clone()];
        while choices.len() < 4 {
            let alt_start = rng.below(ids.len() - cont_len);
            let alt = ids[alt_start..alt_start + cont_len].to_vec();
            if alt != truth {
                choices.push(alt);
            }
        }
        let correct = shuffle_choices(&mut choices, 0, &mut rng);
        items.push(TaskItem { prefix, choices, correct });
    }
    Task { name: "arc-easy-like", items }
}

/// ARC-Challenge-like: 4-way choice with *hard* distractors — local
/// shuffles of the true continuation (same unigram content, wrong order),
/// which only a model with real sequential structure can reject.
pub fn arc_challenge_like(ids: &[u16], n_items: usize, prefix_len: usize, cont_len: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed);
    let total = prefix_len + cont_len;
    let mut items = Vec::with_capacity(n_items);
    while items.len() < n_items {
        let start = rng.below(ids.len() - total);
        let prefix = ids[start..start + prefix_len].to_vec();
        let truth = ids[start + prefix_len..start + total].to_vec();
        let mut choices = vec![truth.clone()];
        let mut attempts = 0;
        while choices.len() < 4 && attempts < 50 {
            attempts += 1;
            let mut alt = truth.clone();
            rng.shuffle(&mut alt);
            if alt != truth && !choices.contains(&alt) {
                choices.push(alt);
            }
        }
        if choices.len() < 4 {
            continue; // degenerate window (all-equal tokens); resample
        }
        let correct = shuffle_choices(&mut choices, 0, &mut rng);
        items.push(TaskItem { prefix, choices, correct });
    }
    Task { name: "arc-challenge-like", items }
}

/// All four tasks with the paper's eval sizes.
pub fn standard_tasks(ids: &[u16], n_items: usize, seq: usize, vocab: usize, seed: u64) -> Vec<Task> {
    let prefix = seq / 2;
    let cont = 8.min(seq / 4).max(2);
    vec![
        lambada_like(ids, n_items, seq.min(64), vocab, seed),
        piqa_like(ids, n_items, prefix.min(32), cont, seed + 1),
        arc_easy_like(ids, n_items, prefix.min(32), cont, seed + 2),
        arc_challenge_like(ids, n_items, prefix.min(32), cont, seed + 3),
    ]
}

/// Shuffle choices, returning the new index of the previously-`correct` one.
fn shuffle_choices(choices: &mut [Vec<u16>], correct: usize, rng: &mut Rng) -> usize {
    let marker = choices[correct].clone();
    rng.shuffle(choices);
    choices.iter().position(|c| *c == marker).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> Vec<u16> {
        (0..5000).map(|i| ((i * 7 + i / 13) % 200) as u16).collect()
    }

    #[test]
    fn lambada_structure() {
        let t = lambada_like(&stream(), 20, 32, 200, 0);
        assert_eq!(t.items.len(), 20);
        for item in &t.items {
            assert_eq!(item.prefix.len(), 31);
            assert_eq!(item.choices.len(), 4);
            assert!(item.correct < 4);
            for c in &item.choices {
                assert_eq!(c.len(), 1);
            }
        }
    }

    #[test]
    fn piqa_structure() {
        let t = piqa_like(&stream(), 15, 16, 4, 1);
        for item in &t.items {
            assert_eq!(item.choices.len(), 2);
            assert_eq!(item.choices[item.correct].len(), 4);
        }
    }

    #[test]
    fn arc_choices_distinct() {
        let t = arc_easy_like(&stream(), 10, 16, 4, 2);
        for item in &t.items {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    assert_ne!(item.choices[i], item.choices[j]);
                }
            }
        }
    }

    #[test]
    fn challenge_distractors_are_permutations() {
        let t = arc_challenge_like(&stream(), 10, 16, 6, 3);
        for item in &t.items {
            let mut truth = item.choices[item.correct].clone();
            truth.sort_unstable();
            for c in &item.choices {
                let mut s = c.clone();
                s.sort_unstable();
                assert_eq!(s, truth, "distractor must be a permutation");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = lambada_like(&stream(), 5, 32, 200, 9);
        let b = lambada_like(&stream(), 5, 32, 200, 9);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prefix, y.prefix);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn standard_tasks_four() {
        let ts = standard_tasks(&stream(), 5, 64, 200, 0);
        let names: Vec<&str> = ts.iter().map(|t| t.name).collect();
        assert_eq!(
            names,
            vec!["lambada-like", "piqa-like", "arc-easy-like", "arc-challenge-like"]
        );
    }
}
