//! ALPSCRP1 corpus artifact loader (vocab + named token-id splits), written
//! by `python/compile/pretrain.py`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

/// Loaded corpus: vocabulary and token-id splits.
pub struct Corpus {
    pub vocab: Vec<String>,
    pub splits: BTreeMap<String, Vec<u16>>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Corpus> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening corpus {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"ALPSCRP1" {
            bail!("bad corpus magic: {magic:?}");
        }
        let vocab_size = read_u32(&mut f)? as usize;
        if vocab_size > 1 << 20 {
            bail!("suspicious vocab size {vocab_size}");
        }
        let mut vocab = Vec::with_capacity(vocab_size);
        for _ in 0..vocab_size {
            vocab.push(read_string(&mut f)?);
        }
        let n_splits = read_u32(&mut f)? as usize;
        let mut splits = BTreeMap::new();
        for _ in 0..n_splits {
            let name = read_string(&mut f)?;
            let n_tokens = read_u32(&mut f)? as usize;
            let mut buf = vec![0u8; n_tokens * 2];
            f.read_exact(&mut buf)?;
            let ids: Vec<u16> = buf
                .chunks_exact(2)
                .map(|b| u16::from_le_bytes([b[0], b[1]]))
                .collect();
            splits.insert(name, ids);
        }
        Ok(Corpus { vocab, splits })
    }

    pub fn split(&self, name: &str) -> Result<&[u16]> {
        self.splits
            .get(name)
            .map(|v| v.as_slice())
            .with_context(|| {
                format!(
                    "missing split '{name}' (have: {:?})",
                    self.splits.keys().collect::<Vec<_>>()
                )
            })
    }

    /// The eval split names in paper order (WikiText2, PTB, C4 analogues).
    pub fn eval_split_names() -> [&'static str; 3] {
        ["wikitext2-like", "ptb-like", "c4-like"]
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_string(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 4096 {
        bail!("suspicious string length {len}");
    }
    let mut b = vec![0u8; len];
    f.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_sample(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"ALPSCRP1").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for w in ["<pad>", "the"] {
            f.write_all(&(w.len() as u32).to_le_bytes()).unwrap();
            f.write_all(w.as_bytes()).unwrap();
        }
        f.write_all(&1u32.to_le_bytes()).unwrap();
        let name = "train";
        f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
        f.write_all(name.as_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for id in [1u16, 0, 1] {
            f.write_all(&id.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn loads_sample() {
        let dir = std::env::temp_dir().join("alps_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.bin");
        write_sample(&p);
        let c = Corpus::load(&p).unwrap();
        assert_eq!(c.vocab, vec!["<pad>", "the"]);
        assert_eq!(c.split("train").unwrap(), &[1, 0, 1]);
        assert!(c.split("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("alps_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"WRONG!!!xxxx").unwrap();
        assert!(Corpus::load(&p).is_err());
    }
}
