//! Symmetric eigendecomposition: Householder tridiagonalization (tred2)
//! followed by the implicit-shift QL iteration (tql2) — the classic
//! EISPACK pair, in f64 internally for stability.
//!
//! This is the factorization ALPS caches so the ADMM W-update
//! (H + rho I)^-1 B can be applied for *any* rho with two matmuls
//! (paper Sec. 3.2 "Computational cost").

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Eigendecomposition H = Q diag(vals) Q^T of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues in ascending order.
    pub vals: Vec<f32>,
    /// Orthonormal eigenvectors as *columns* of Q (row-major storage).
    pub q: Matrix,
}

impl SymEig {
    /// Compute the decomposition. `h` must be symmetric (checked loosely).
    pub fn new(h: &Matrix) -> Result<Self> {
        if h.rows != h.cols {
            bail!("eigh: matrix must be square, got {}x{}", h.rows, h.cols);
        }
        let n = h.rows;
        if n == 0 {
            bail!("eigh: empty matrix");
        }
        // f64 working copy (column storage irrelevant: symmetric input)
        let mut a: Vec<f64> = h.data.iter().map(|x| *x as f64).collect();
        let mut d = vec![0.0f64; n];
        let mut e = vec![0.0f64; n];
        tred2(&mut a, n, &mut d, &mut e);
        tql2(&mut a, n, &mut d, &mut e)?;
        // `a` now holds eigenvectors in columns; d the ascending eigenvalues.
        let q = Matrix::from_vec(n, n, a.iter().map(|x| *x as f32).collect());
        let vals = d.iter().map(|x| *x as f32).collect();
        Ok(SymEig { vals, q })
    }

    /// Reconstruct Q diag(f(vals)) Q^T B  — the ridge-solve primitive:
    /// with f = 1/(vals + rho) this applies (H + rho I)^-1.
    pub fn apply_fn(&self, f: impl Fn(f32) -> f32, b: &Matrix) -> Matrix {
        use super::matmul::{matmul, matmul_tn};
        let mut qtb = matmul_tn(&self.q, b); // Q^T B
        for (i, lam) in self.vals.iter().enumerate() {
            let s = f(*lam);
            qtb.scale_row(i, s);
        }
        matmul(&self.q, &qtb)
    }

    /// Apply (H + rho I)^{-1} to B.
    pub fn ridge_solve(&self, rho: f32, b: &Matrix) -> Matrix {
        self.apply_fn(|lam| 1.0 / (lam + rho), b)
    }
}

/// Householder reduction to tridiagonal form (EISPACK tred2).
/// On exit `a` holds the orthogonal transform Q (columns), `d` the diagonal,
/// `e` the off-diagonal (e[0] = 0).
fn tred2(a: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    let at = |a: &[f64], i: usize, j: usize| a[i * n + j];

    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0f64;
        let mut scale = 0.0f64;
        if l > 0 {
            for k in 0..=l {
                scale += at(a, i, k).abs();
            }
            if scale == 0.0 {
                e[i] = at(a, i, l);
            } else {
                for k in 0..=l {
                    a[i * n + k] /= scale;
                    h += at(a, i, k) * at(a, i, k);
                }
                let mut f = at(a, i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    a[j * n + i] = at(a, i, j) / h;
                    let mut g = 0.0f64;
                    for k in 0..=j {
                        g += at(a, j, k) * at(a, i, k);
                    }
                    for k in (j + 1)..=l {
                        g += at(a, k, j) * at(a, i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * at(a, i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let fj = at(a, i, j);
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        a[j * n + k] -= fj * e[k] + gj * at(a, i, k);
                    }
                }
            }
        } else {
            e[i] = at(a, i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0f64;
                for k in 0..l {
                    g += at(a, i, k) * at(a, k, j);
                }
                for k in 0..l {
                    a[k * n + j] -= g * at(a, k, i);
                }
            }
        }
        d[i] = at(a, i, i);
        a[i * n + i] = 1.0;
        for j in 0..l {
            a[j * n + i] = 0.0;
            a[i * n + j] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration on the tridiagonal form (EISPACK tql2),
/// accumulating the transform into `a`. Eigenvalues sorted ascending.
fn tql2(a: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small off-diagonal to split
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                bail!("eigh: QL failed to converge at index {l}");
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let mut s = 1.0f64;
            let mut c = 1.0f64;
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate eigenvectors
                for k in 0..n {
                    f = a[k * n + i + 1];
                    a[k * n + i + 1] = s * a[k * n + i] + c * f;
                    a[k * n + i] = c * a[k * n + i] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // sort ascending, permuting eigenvector columns
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in (i + 1)..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d[k] = d[i];
            d[i] = p;
            for r in 0..n {
                a.swap(r * n + i, r * n + k);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{gram, matmul};
    use crate::util::Rng;

    fn reconstruct(eig: &SymEig) -> Matrix {
        let n = eig.vals.len();
        let mut lam_qt = eig.q.transpose();
        for i in 0..n {
            lam_qt.scale_row(i, eig.vals[i]);
        }
        matmul(&eig.q, &lam_qt)
    }

    #[test]
    fn diagonal_matrix() {
        let h = Matrix::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let e = SymEig::new(&h).unwrap();
        assert!((e.vals[0] - 1.0).abs() < 1e-5);
        assert!((e.vals[1] - 2.0).abs() < 1e-5);
        assert!((e.vals[2] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let h = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let e = SymEig::new(&h).unwrap();
        assert!((e.vals[0] - 1.0).abs() < 1e-5);
        assert!((e.vals[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn reconstruction_random_gram() {
        let mut rng = Rng::new(11);
        for &n in &[2usize, 5, 16, 40] {
            let x = Matrix::randn(n + 10, n, &mut rng);
            let h = gram(&x);
            let e = SymEig::new(&h).unwrap();
            let r = reconstruct(&e);
            let scale = h.fro_norm().max(1.0);
            assert!(
                r.sub(&h).fro_norm() / scale < 1e-4,
                "n={n} err={}",
                r.sub(&h).fro_norm() / scale
            );
        }
    }

    #[test]
    fn orthonormal_eigenvectors() {
        let mut rng = Rng::new(12);
        let x = Matrix::randn(30, 12, &mut rng);
        let h = gram(&x);
        let e = SymEig::new(&h).unwrap();
        let qtq = matmul(&e.q.transpose(), &e.q);
        assert!(qtq.max_abs_diff(&Matrix::identity(12)) < 1e-4);
    }

    #[test]
    fn eigenvalues_ascending_nonnegative_for_gram() {
        let mut rng = Rng::new(13);
        let x = Matrix::randn(25, 10, &mut rng);
        let e = SymEig::new(&gram(&x)).unwrap();
        for w in e.vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-6);
        }
        assert!(e.vals[0] > -1e-3); // PSD up to rounding
    }

    #[test]
    fn ridge_solve_matches_direct() {
        let mut rng = Rng::new(14);
        let x = Matrix::randn(30, 8, &mut rng);
        let h = gram(&x);
        let e = SymEig::new(&h).unwrap();
        let b = Matrix::randn(8, 3, &mut rng);
        let rho = 0.7f32;
        let w = e.ridge_solve(rho, &b);
        // check (H + rho I) w == b
        let mut hr = h.clone();
        for i in 0..8 {
            *hr.at_mut(i, i) += rho;
        }
        let back = matmul(&hr, &w);
        assert!(back.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn rank_deficient_ok() {
        // gram of a rank-1 X: one positive eigenvalue, rest ~0
        let x = Matrix::from_vec(4, 3, vec![1., 2., 3., 2., 4., 6., 3., 6., 9., 4., 8., 12.]);
        let e = SymEig::new(&gram(&x)).unwrap();
        assert!(e.vals[2] > 1.0);
        assert!(e.vals[0].abs() < 1e-3 && e.vals[1].abs() < 1e-3);
    }

    #[test]
    fn non_square_rejected() {
        assert!(SymEig::new(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn size_one() {
        let h = Matrix::from_vec(1, 1, vec![5.0]);
        let e = SymEig::new(&h).unwrap();
        assert!((e.vals[0] - 5.0).abs() < 1e-6);
        assert!((e.q.at(0, 0).abs() - 1.0).abs() < 1e-6);
    }
}
