//! CSR sparse matrix — pruned-weight inference kernels (the payoff side of
//! pruning: sparse matmul skips the zeros the pruner created).

use super::matrix::Matrix;

/// Compressed sparse row matrix. Row pointers are `u32` (not `usize`) to
/// halve the bookkeeping footprint; `from_dense` guards the nnz overflow.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Convert from dense, dropping exact zeros.
    ///
    /// Panics if the matrix holds more than `u32::MAX` nonzeros — beyond
    /// the u32 indptr representation (a 16 GiB+ values array; none of our
    /// models come within orders of magnitude of that).
    pub fn from_dense(m: &Matrix) -> Self {
        assert!(
            m.rows * m.cols <= u32::MAX as usize || m.nnz() <= u32::MAX as usize,
            "matrix nnz overflows u32 CSR row pointers"
        );
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    /// Half-open nonzero range of row `r` into `indices`/`values`.
    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r] as usize..self.indptr[r + 1] as usize
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_range(r) {
                *m.at_mut(r, self.indices[i] as usize) = self.values[i];
            }
        }
        m
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for i in self.row_range(r) {
                acc += self.values[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// y = x A for a single activation row x (len == A.rows) — the
    /// KV-cache decode shape: one token's activations against the pruned
    /// weight matrix.
    pub fn row_matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0f32; self.cols];
        for (r, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for i in self.row_range(r) {
                y[self.indices[i] as usize] += xv * self.values[i];
            }
        }
        y
    }

    /// Dense @ sparse: Y = X A where A is this CSR (shape cols of X == A.rows).
    /// This is the inference shape: activations [tokens, n_in] times pruned
    /// weights [n_in, n_out].
    pub fn left_matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.rows);
        let mut y = Matrix::zeros(x.rows, self.cols);
        for t in 0..x.rows {
            let xrow = x.row(t);
            let yrow = y.row_mut(t);
            for r in 0..self.rows {
                let xv = xrow[r];
                if xv == 0.0 {
                    continue;
                }
                for i in self.row_range(r) {
                    yrow[self.indices[i] as usize] += xv * self.values[i];
                }
            }
        }
        y
    }

    /// Bytes of the CSR representation (f32 values + u32 col indices +
    /// u32 row pointers).
    pub fn bytes(&self) -> usize {
        self.nnz() * (4 + 4) + (self.rows + 1) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::Rng;

    fn sparse_random(rows: usize, cols: usize, density: f64, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            if rng.uniform() < density {
                *v = rng.gaussian();
            }
        }
        m
    }

    #[test]
    fn dense_roundtrip() {
        let m = sparse_random(20, 15, 0.3, 0);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.to_dense(), m);
        assert_eq!(csr.nnz(), m.nnz());
    }

    #[test]
    fn empty_matrix() {
        let m = Matrix::zeros(5, 5);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.density(), 0.0);
        assert_eq!(csr.matvec(&[1.0; 5]), vec![0.0; 5]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sparse_random(12, 9, 0.4, 1);
        let csr = Csr::from_dense(&m);
        let mut rng = Rng::new(2);
        let x = rng.gaussian_vec(9);
        let expect = crate::linalg::matmul::matvec(&m, &x);
        let got = csr.matvec(&x);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4);
        }
    }

    #[test]
    fn left_matmul_matches_dense() {
        let w = sparse_random(16, 10, 0.25, 3);
        let csr = Csr::from_dense(&w);
        let mut rng = Rng::new(4);
        let x = Matrix::randn(7, 16, &mut rng);
        let expect = matmul(&x, &w);
        let got = csr.left_matmul(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn row_matvec_matches_left_matmul() {
        let w = sparse_random(14, 11, 0.3, 5);
        let csr = Csr::from_dense(&w);
        let mut rng = Rng::new(6);
        let x = Matrix::randn(3, 14, &mut rng);
        let full = csr.left_matmul(&x);
        for t in 0..x.rows {
            let got = csr.row_matvec(x.row(t));
            for (c, g) in got.iter().enumerate() {
                assert!((g - full.at(t, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn bytes_accounting_u32() {
        let m = sparse_random(10, 10, 0.2, 7);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.bytes(), csr.nnz() * 8 + 11 * 4);
    }

    #[test]
    fn density_computation() {
        let mut m = Matrix::zeros(10, 10);
        for i in 0..30 {
            m.data[i * 3 % 100] = 1.0;
        }
        let csr = Csr::from_dense(&m);
        assert!((csr.density() - csr.nnz() as f64 / 100.0).abs() < 1e-12);
    }
}
