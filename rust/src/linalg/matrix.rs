//! Row-major f32 matrix with the small set of ops the pruning stack needs.

use crate::util::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.gaussian_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            *self.at_mut(r, c) = v[r];
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on big matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    pub fn diag(&self) -> Vec<f32> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.at(i, i)).collect()
    }

    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|x| **x != 0.0).count()
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Hadamard (elementwise) product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect(),
        }
    }

    /// axpy in place: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale row r by s (used by the B.1 diagonal preconditioning).
    pub fn scale_row(&mut self, r: usize, s: f32) {
        for v in self.row_mut(r) {
            *v *= s;
        }
    }

    /// Frobenius inner product <A, B>.
    pub fn dot(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum()
    }

    /// 0/1 support mask of the non-zero entries.
    pub fn support_mask(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| if *x != 0.0 { 1.0 } else { 0.0 }).collect(),
        }
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn identity_diag() {
        let i = Matrix::identity(4);
        assert_eq!(i.diag(), vec![1.0; 4]);
        assert_eq!(i.nnz(), 4);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let m = Matrix::randn(37, 53, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_values() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), 6.0);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![4., 3., 2., 1.]);
        assert_eq!(a.add(&b).data, vec![5., 5., 5., 5.]);
        assert_eq!(a.sub(&b).data, vec![-3., -1., 1., 3.]);
        assert_eq!(a.hadamard(&b).data, vec![4., 6., 6., 4.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6., 8.]);
    }

    #[test]
    fn axpy_in_place() {
        let mut a = Matrix::from_vec(1, 3, vec![1., 1., 1.]);
        let b = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert!((a.fro_norm_sq() - 25.0).abs() < 1e-10);
    }

    #[test]
    fn support_mask_and_nnz() {
        let a = Matrix::from_vec(2, 2, vec![0., 2., 0., -4.]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.support_mask().data, vec![0., 1., 0., 1.]);
    }

    #[test]
    fn dot_product() {
        let a = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Matrix::from_vec(1, 3, vec![4., 5., 6.]);
        assert!((a.dot(&b) - 32.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_add_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.add(&b);
    }
}
