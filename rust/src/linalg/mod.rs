//! Dense + sparse linear algebra substrate (no external BLAS/LAPACK).
//!
//! Everything ALPS needs that the paper got from PyTorch/CUDA:
//! row-major f32 matrices, blocked multi-threaded matmul, symmetric
//! eigendecomposition (Householder tridiagonalization + implicit-QL),
//! Cholesky factorization and solves, (preconditioned) conjugate gradient,
//! and CSR sparse kernels for pruned-weight inference.

pub mod cholesky;
pub mod eigh;
pub mod matmul;
pub mod matrix;
pub mod solve;
pub mod sparse;

pub use cholesky::Cholesky;
pub use eigh::SymEig;
pub use matrix::Matrix;
pub use sparse::Csr;
