//! Cholesky factorization + solves — the backsolve baseline of Table 1 and
//! the inner solver of the SparseGPT reimplementation.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L with A = L L^T.
pub struct Cholesky {
    pub l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix (f64 accumulation).
    pub fn new(a: &Matrix) -> Result<Self> {
        if a.rows != a.cols {
            bail!("cholesky: non-square {}x{}", a.rows, a.cols);
        }
        let n = a.rows;
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a.at(i, j) as f64;
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!("cholesky: matrix not positive definite at pivot {i} (sum={sum})");
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(Cholesky {
            l: Matrix::from_vec(n, n, l.iter().map(|x| *x as f32).collect()),
        })
    }

    /// Solve A x = b (via L y = b then L^T x = y).
    pub fn solve_vec(&self, b: &[f32]) -> Vec<f32> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut sum = b[i] as f64;
            for k in 0..i {
                sum -= self.l.at(i, k) as f64 * y[k];
            }
            y[i] = sum / self.l.at(i, i) as f64;
        }
        let mut x = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l.at(k, i) as f64 * x[k];
            }
            x[i] = sum / self.l.at(i, i) as f64;
        }
        x.iter().map(|v| *v as f32).collect()
    }

    /// Solve A X = B column-by-column.
    pub fn solve(&self, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(b.rows, b.cols);
        for c in 0..b.cols {
            let col = b.col(c);
            out.set_col(c, &self.solve_vec(&col));
        }
        out
    }

    /// Inverse via n unit-vector solves (used by SparseGPT's H^-1).
    pub fn inverse(&self) -> Matrix {
        let n = self.l.rows;
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0f32; n];
        for i in 0..n {
            e[i] = 1.0;
            inv.set_col(i, &self.solve_vec(&e));
            e[i] = 0.0;
        }
        inv
    }
}

/// Solve the SPD system A x = b directly (factor + solve).
pub fn spd_solve(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    Ok(Cholesky::new(a)?.solve(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{gram, matmul};
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(n + 8, n, &mut rng);
        let mut h = gram(&x);
        for i in 0..n {
            *h.at_mut(i, i) += 0.1; // well-conditioned
        }
        h
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(12, 0);
        let ch = Cholesky::new(&a).unwrap();
        let llt = matmul(&ch.l, &ch.l.transpose());
        assert!(llt.sub(&a).fro_norm() / a.fro_norm() < 1e-4);
    }

    #[test]
    fn solve_vec_residual() {
        let a = spd(10, 1);
        let mut rng = Rng::new(2);
        let b: Vec<f32> = rng.gaussian_vec(10);
        let x = Cholesky::new(&a).unwrap().solve_vec(&b);
        let ax = crate::linalg::matmul::matvec(&a, &x);
        for i in 0..10 {
            assert!((ax[i] - b[i]).abs() < 1e-3, "{} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn solve_matrix_residual() {
        let a = spd(8, 3);
        let mut rng = Rng::new(4);
        let b = Matrix::randn(8, 5, &mut rng);
        let x = spd_solve(&a, &b).unwrap();
        assert!(matmul(&a, &x).max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(6, 5);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Matrix::identity(6)) < 1e-3);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(Cholesky::new(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn identity_factor() {
        let ch = Cholesky::new(&Matrix::identity(5)).unwrap();
        assert!(ch.l.max_abs_diff(&Matrix::identity(5)) < 1e-6);
    }
}
