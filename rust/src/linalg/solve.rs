//! Iterative solvers: CG and the support-projected, Jacobi-preconditioned
//! CG of Algorithm 2 (native path; the artifact path runs the same math
//! inside one HLO while-loop).

use super::matmul::{matmul, matmul_into, matvec};
use super::matrix::Matrix;

/// Result of an iterative solve.
#[derive(Debug, Clone)]
pub struct SolveInfo {
    pub iters: usize,
    pub residual: f64,
}

/// Plain conjugate gradient on A x = b (A SPD). Returns (x, info).
pub fn cg(a: &Matrix, b: &[f32], max_iters: usize, tol: f64) -> (Vec<f32>, SolveInfo) {
    let n = b.len();
    let mut x = vec![0.0f32; n];
    let mut r: Vec<f32> = b.to_vec();
    let mut p = r.clone();
    let mut rs: f64 = r.iter().map(|v| (*v as f64) * (*v as f64)).sum();
    let b_norm = rs.sqrt().max(1e-30);
    let mut iters = 0;
    for _ in 0..max_iters {
        if rs.sqrt() / b_norm < tol {
            break;
        }
        let ap = matvec(a, &p);
        let pap: f64 = p.iter().zip(&ap).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        if pap <= 0.0 {
            break;
        }
        let alpha = rs / pap;
        for i in 0..n {
            x[i] += (alpha * p[i] as f64) as f32;
            r[i] -= (alpha * ap[i] as f64) as f32;
        }
        let rs_new: f64 = r.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + (beta * p[i] as f64) as f32;
        }
        rs = rs_new;
        iters += 1;
    }
    (x, SolveInfo { iters, residual: rs.sqrt() })
}

/// Algorithm 2: vectorized PCG over all columns simultaneously, with the
/// residual re-projected onto the support mask every iteration and a
/// Jacobi (diagonal) preconditioner.
///
/// Solves  min ||X What - X W||_F^2  s.t. supp(W) in S, given
/// h = X^T X, g = X^T X What, an initial W0 and the 0/1 mask of S.
pub fn pcg_support(
    h: &Matrix,
    g: &Matrix,
    w0: &Matrix,
    mask: &Matrix,
    max_iters: usize,
    tol: f64,
) -> (Matrix, SolveInfo) {
    let n = h.rows;
    assert_eq!(h.cols, n);
    assert_eq!((g.rows, g.cols), (w0.rows, w0.cols));
    assert_eq!((mask.rows, mask.cols), (w0.rows, w0.cols));

    let invdiag: Vec<f32> = (0..n).map(|i| 1.0 / h.at(i, i).max(1e-12)).collect();
    let cols = w0.cols;

    let mut w = w0.hadamard(mask);
    // R0 = (G - H W0) projected on S
    let mut r = g.sub(&matmul(h, &w)).hadamard(mask);
    let mut z = r.clone();
    for i in 0..n {
        z.scale_row(i, invdiag[i]);
    }
    let mut p = z.clone();
    // preallocated H@P buffer — the loop below is allocation-free (§Perf)
    let mut hp = Matrix::zeros(r.rows, r.cols);
    let mut rz = r.dot(&z);
    let g_norm = g.fro_norm_sq().sqrt().max(1e-30);
    let mut iters = 0;

    for _ in 0..max_iters {
        let res = r.fro_norm_sq().sqrt();
        if res / g_norm < tol {
            break;
        }
        matmul_into(h, &p, &mut hp);
        let php = p.dot(&hp);
        if php <= 0.0 {
            break;
        }
        let alpha = (rz / php) as f32;
        // fused elementwise pass (the rust mirror of kernels/pcg_step.py):
        //   w += alpha p;  r = (r - alpha hp) * mask;  z = invdiag * r
        let mut rz_new = 0.0f64;
        for row in 0..n {
            let base = row * cols;
            let inv = invdiag[row];
            let wr = &mut w.data[base..base + cols];
            let rr = &mut r.data[base..base + cols];
            let zr = &mut z.data[base..base + cols];
            let pr = &p.data[base..base + cols];
            let hpr = &hp.data[base..base + cols];
            let mr = &mask.data[base..base + cols];
            for j in 0..cols {
                wr[j] += alpha * pr[j];
                let rv = (rr[j] - alpha * hpr[j]) * mr[j];
                rr[j] = rv;
                let zv = inv * rv;
                zr[j] = zv;
                rz_new += (rv as f64) * (zv as f64);
            }
        }
        let beta = if rz > 0.0 { (rz_new / rz) as f32 } else { 0.0 };
        // p = z + beta p
        for (pv, zv) in p.data.iter_mut().zip(&z.data) {
            *pv = zv + beta * *pv;
        }
        rz = rz_new;
        iters += 1;
    }
    let residual = r.fro_norm_sq().sqrt();
    (w, SolveInfo { iters, residual })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::spd_solve;
    use crate::linalg::matmul::gram;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(n + 6, n, &mut rng);
        let mut h = gram(&x);
        for i in 0..n {
            *h.at_mut(i, i) += 0.5;
        }
        h
    }

    #[test]
    fn cg_matches_cholesky() {
        let a = spd(12, 0);
        let mut rng = Rng::new(1);
        let b: Vec<f32> = rng.gaussian_vec(12);
        let (x, info) = cg(&a, &b, 200, 1e-10);
        let bm = Matrix::from_vec(12, 1, b.clone());
        let expect = spd_solve(&a, &bm).unwrap();
        for i in 0..12 {
            assert!((x[i] - expect.at(i, 0)).abs() < 1e-3);
        }
        assert!(info.iters <= 200);
    }

    #[test]
    fn cg_zero_rhs() {
        let a = spd(6, 2);
        let (x, info) = cg(&a, &[0.0; 6], 50, 1e-10);
        assert!(x.iter().all(|v| v.abs() < 1e-6));
        assert_eq!(info.iters, 0);
    }

    #[test]
    fn pcg_full_mask_matches_dense() {
        // with mask all-ones, PCG solves H W = G exactly
        let mut rng = Rng::new(3);
        let x = Matrix::randn(30, 10, &mut rng);
        let h = gram(&x);
        let what = Matrix::randn(10, 4, &mut rng);
        let g = matmul(&h, &what);
        let mask = Matrix::from_vec(10, 4, vec![1.0; 40]);
        let (w, _) = pcg_support(&h, &g, &Matrix::zeros(10, 4), &mask, 300, 1e-10);
        assert!(w.max_abs_diff(&what) < 1e-2);
    }

    #[test]
    fn pcg_respects_support() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(30, 8, &mut rng);
        let h = gram(&x);
        let what = Matrix::randn(8, 4, &mut rng);
        let g = matmul(&h, &what);
        let mask_data: Vec<f32> = (0..32).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let mask = Matrix::from_vec(8, 4, mask_data);
        let (w, _) = pcg_support(&h, &g, &Matrix::zeros(8, 4), &mask, 50, 1e-10);
        for i in 0..32 {
            if mask.data[i] == 0.0 {
                assert_eq!(w.data[i], 0.0);
            }
        }
    }

    #[test]
    fn pcg_monotone_objective() {
        // objective ||X What - X W||^2 must not increase across iterations
        let mut rng = Rng::new(5);
        let x = Matrix::randn(40, 12, &mut rng);
        let h = gram(&x);
        let what = Matrix::randn(12, 6, &mut rng);
        let g = matmul(&h, &what);
        let mask_data: Vec<f32> = (0..72).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let mask = Matrix::from_vec(12, 6, mask_data);
        let obj = |w: &Matrix| {
            let xw = matmul(&x, w);
            let xwhat = matmul(&x, &what);
            xw.sub(&xwhat).fro_norm_sq()
        };
        let mut prev = f64::INFINITY;
        for iters in [1usize, 2, 4, 8, 16] {
            let (w, _) = pcg_support(&h, &g, &Matrix::zeros(12, 6), &mask, iters, 1e-14);
            let o = obj(&w);
            assert!(o <= prev + 1e-6, "iters={iters}: {o} > {prev}");
            prev = o;
        }
    }

    #[test]
    fn pcg_matches_backsolve_on_support() {
        // per-column restricted least squares vs PCG
        let mut rng = Rng::new(6);
        let x = Matrix::randn(50, 10, &mut rng);
        let h = gram(&x);
        let what = Matrix::randn(10, 3, &mut rng);
        let g = matmul(&h, &what);
        let mask_data: Vec<f32> = (0..30).map(|i| if (i * 7) % 3 != 0 { 1.0 } else { 0.0 }).collect();
        let mask = Matrix::from_vec(10, 3, mask_data);
        let (w, _) = pcg_support(&h, &g, &Matrix::zeros(10, 3), &mask, 400, 1e-12);

        // backsolve: for each column, solve H_SS w_S = g_S
        for c in 0..3 {
            let support: Vec<usize> = (0..10).filter(|&i| mask.at(i, c) != 0.0).collect();
            let s = support.len();
            let mut hs = Matrix::zeros(s, s);
            for (ii, &i) in support.iter().enumerate() {
                for (jj, &j) in support.iter().enumerate() {
                    *hs.at_mut(ii, jj) = h.at(i, j);
                }
            }
            let mut gs = Matrix::zeros(s, 1);
            for (ii, &i) in support.iter().enumerate() {
                *gs.at_mut(ii, 0) = g.at(i, c);
            }
            let ws = spd_solve(&hs, &gs).unwrap();
            for (ii, &i) in support.iter().enumerate() {
                assert!(
                    (w.at(i, c) - ws.at(ii, 0)).abs() < 5e-2,
                    "col {c} idx {i}: {} vs {}",
                    w.at(i, c),
                    ws.at(ii, 0)
                );
            }
        }
    }
}
