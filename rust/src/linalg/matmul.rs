//! Blocked, multi-threaded matmul — the L3 hot path when the PJRT runtime
//! is not in play (native baselines, tests, small shapes).
//!
//! Kernel structure mirrors the Pallas kernel (DESIGN.md §Hardware-
//! Adaptation): an MR x NR register-blocked micro-kernel keeps the C
//! accumulators in SIMD registers across the whole K loop (f32
//! accumulation), and rows of C are partitioned across threads (each
//! thread owns disjoint output strips, so no synchronization). See
//! EXPERIMENTS.md §Perf for the optimization log.

use super::matrix::Matrix;

/// C = A @ B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// Below this many f32 multiply-adds the explicit-transpose copy is the
/// dominant cost and the product runs single-threaded anyway (same
/// threshold as the threading cutoff in [`matmul_into`]), so `matmul_tn`
/// takes the allocation-free strided path. Above it, the transposed copy
/// amortizes: A^T rows become contiguous for the register-blocked kernel
/// and the row partition fans across the thread pool.
const TN_STRIDED_CUTOFF: usize = 64 * 64 * 64;

/// C = A^T @ B.
///
/// Small products go through [`matmul_tn_strided`] (no A^T is ever
/// materialized); large ones take an explicit transpose + the blocked
/// threaded [`matmul`]. Both accumulate over k in ascending order, so the
/// two paths agree bitwise on finite inputs.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dims: {}x{} vs {}x{}", a.rows, a.cols, b.rows, b.cols);
    if a.rows * a.cols * b.cols <= TN_STRIDED_CUTOFF {
        return matmul_tn_strided(a, b);
    }
    let at = a.transpose();
    matmul(&at, b)
}

/// Strided kernel for C = A^T @ B: for each shared row k, rank-1 update
/// C[i, :] += A[k, i] * B[k, :]. Both operands stream row-contiguously —
/// no transpose allocation, no strided inner loop.
fn matmul_tn_strided(a: &Matrix, b: &Matrix) -> Matrix {
    let n_dim = b.cols;
    let mut c = Matrix::zeros(a.cols, n_dim);
    for k in 0..a.rows {
        let arow = a.row(k);
        let brow = b.row(k);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n_dim..(i + 1) * n_dim];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// Gram matrix H = X^T X via [`matmul_tn`] (one shared A^T-product
/// implementation instead of the duplicated explicit-transpose pattern),
/// then symmetrized.
pub fn gram(x: &Matrix) -> Matrix {
    let n = x.cols;
    let mut h = matmul_tn(x, x);
    // enforce exact symmetry (floating point drift breaks eigh otherwise)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = 0.5 * (h.at(i, j) + h.at(j, i));
            *h.at_mut(i, j) = v;
            *h.at_mut(j, i) = v;
        }
    }
    h
}

/// y = A @ x for a vector x.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for r in 0..a.rows {
        let row = a.row(r);
        let mut acc = 0.0f64;
        for (av, xv) in row.iter().zip(x) {
            acc += (*av as f64) * (*xv as f64);
        }
        y[r] = acc as f32;
    }
    y
}

/// Number of worker threads: the `ALPS_THREADS` env override when set to a
/// positive integer (read once — serve benches pin it for reproducibility
/// on shared CI machines), else cores - 1, at least 1.
pub fn num_threads() -> usize {
    static OVERRIDE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    let ov = OVERRIDE
        .get_or_init(|| std::env::var("ALPS_THREADS").ok().and_then(|v| parse_threads(&v)));
    if let Some(n) = ov {
        return *n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

/// Parse an `ALPS_THREADS` value; `None` for anything non-positive/garbled.
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|&n| n > 0)
}

/// Micro-kernel geometry: MR rows of A against an NR-wide strip of B, with
/// the C accumulators living in SIMD registers across the whole K loop —
/// one B load is reused MR times, so the kernel is compute-bound instead
/// of L1-bound (§Perf: 7 -> ~20 GFLOP/s on one AVX-512 core).
const MR: usize = 4;
const NR: usize = 64;

/// C += A @ B restricted to C rows [r0, r1).
fn matmul_rows(a: &Matrix, b: &Matrix, c: &mut [f32], r0: usize, r1: usize) {
    let k_dim = a.cols;
    let n_dim = b.cols;
    let mut r = r0;
    // full MR-row blocks through the register-blocked micro-kernel
    while r + MR <= r1 {
        let mut nb = 0;
        while nb + NR <= n_dim {
            microkernel::<MR, NR>(a, b, c, r, r0, nb, k_dim, n_dim);
            nb += NR;
        }
        if nb < n_dim {
            scalar_tail(a, b, c, r, (r + MR).min(r1), r0, nb, n_dim, k_dim, n_dim);
        }
        r += MR;
    }
    // remainder rows
    if r < r1 {
        scalar_tail(a, b, c, r, r1, r0, 0, n_dim, k_dim, n_dim);
    }
}

/// MR x NR register-blocked kernel over the full K dimension.
#[inline(always)]
fn microkernel<const MRC: usize, const NRC: usize>(
    a: &Matrix,
    b: &Matrix,
    c: &mut [f32],
    r: usize,
    r0: usize,
    nb: usize,
    k_dim: usize,
    n_dim: usize,
) {
    let mut acc = [[0.0f32; NRC]; MRC];
    for k in 0..k_dim {
        let brow = &b.data[k * n_dim + nb..k * n_dim + nb + NRC];
        for i in 0..MRC {
            let av = a.data[(r + i) * k_dim + k];
            let accr = &mut acc[i];
            for j in 0..NRC {
                accr[j] += av * brow[j];
            }
        }
    }
    for i in 0..MRC {
        let dst = &mut c[(r + i - r0) * n_dim + nb..(r + i - r0) * n_dim + nb + NRC];
        for j in 0..NRC {
            dst[j] += acc[i][j];
        }
    }
}

/// Scalar fallback for row/column tails.
#[allow(clippy::too_many_arguments)]
fn scalar_tail(
    a: &Matrix,
    b: &Matrix,
    c: &mut [f32],
    r_start: usize,
    r_end: usize,
    r0: usize,
    n_start: usize,
    n_end: usize,
    k_dim: usize,
    n_dim: usize,
) {
    for r in r_start..r_end {
        let arow = &a.data[r * k_dim..(r + 1) * k_dim];
        let crow = &mut c[(r - r0) * n_dim..(r - r0 + 1) * n_dim];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[k * n_dim..k * n_dim + n_dim];
            for j in n_start..n_end {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// C = A @ B into a preallocated C (zeroed here).
///
/// Row-partitioned across the thread pool; both the serve decode batch
/// (`[batch, d]`) and the batched prefill (`[prompt, d]`) land here, so a
/// multi-row prefill fans its rows across workers while a single decode
/// row stays on the calling thread (below the threading cutoff).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.iter_mut().for_each(|v| *v = 0.0);
    let nt = num_threads().min(a.rows.max(1));
    if nt <= 1 || a.rows * a.cols * b.cols < 64 * 64 * 64 {
        let (r0, r1) = (0, a.rows);
        let n_dim = b.cols;
        let mut strip = vec![0.0f32; (r1 - r0) * n_dim];
        matmul_rows(a, b, &mut strip, r0, r1);
        c.data.copy_from_slice(&strip);
        return;
    }
    let rows_per = a.rows.div_ceil(nt);
    let n_dim = b.cols;
    let chunks: Vec<(usize, usize)> = (0..nt)
        .map(|t| (t * rows_per, ((t + 1) * rows_per).min(a.rows)))
        .filter(|(r0, r1)| r1 > r0)
        .collect();
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(chunks.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .iter()
            .map(|&(r0, r1)| {
                s.spawn(move || {
                    let mut strip = vec![0.0f32; (r1 - r0) * n_dim];
                    matmul_rows(a, b, &mut strip, r0, r1);
                    strip
                })
            })
            .collect();
        for h in handles {
            out.push(h.join().expect("matmul worker panicked"));
        }
    });
    let mut offset = 0;
    for strip in out {
        c.data[offset..offset + strip.len()].copy_from_slice(&strip);
        offset += strip.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                for j in 0..b.cols {
                    c.data[i * b.cols + j] += a.at(i, k) * b.at(k, j);
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3, 4, 5), (1, 1, 1), (7, 13, 2), (16, 16, 16)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
        }
    }

    #[test]
    fn matches_naive_threaded_sizes() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(130, 70, &mut rng);
        let b = Matrix::randn(70, 90, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 20, &mut rng);
        assert!(matmul(&a, &Matrix::identity(20)).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(50, 12, &mut rng);
        let h = gram(&x);
        for i in 0..12 {
            assert!(h.at(i, i) > 0.0);
            for j in 0..12 {
                assert_eq!(h.at(i, j), h.at(j, i));
            }
        }
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(40, 12, &mut rng);
        let b = Matrix::randn(40, 9, &mut rng);
        let direct = matmul(&a.transpose(), &b);
        assert!(matmul_tn(&a, &b).max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn matmul_tn_strided_and_transpose_paths_agree() {
        let mut rng = Rng::new(8);
        // spans the cutoff: small goes strided, large goes transpose+matmul
        for &(rows, k, n) in &[(10, 4, 3), (64, 64, 64), (70, 64, 64), (30, 90, 110)] {
            let a = Matrix::randn(rows, k, &mut rng);
            let b = Matrix::randn(rows, n, &mut rng);
            let strided = matmul_tn_strided(&a, &b);
            let transposed = matmul(&a.transpose(), &b);
            // identical k-ascending accumulation order => tight agreement
            assert!(
                strided.max_abs_diff(&transposed) < 1e-5,
                "{rows}x{k} ^T @ {rows}x{n}"
            );
            assert!(matmul_tn(&a, &b).max_abs_diff(&transposed) < 1e-5);
        }
    }

    #[test]
    fn gram_matches_matmul_tn() {
        // gram is matmul_tn(x, x) + exact symmetrization — the shared path
        let mut rng = Rng::new(9);
        for &(rows, n) in &[(50, 12), (80, 66)] {
            let x = Matrix::randn(rows, n, &mut rng);
            let h = gram(&x);
            let tn = matmul_tn(&x, &x);
            assert!(h.max_abs_diff(&tn) < 1e-5, "{rows}x{n}");
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(h.at(i, j), h.at(j, i));
                }
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(15, 8, &mut rng);
        let x: Vec<f32> = rng.gaussian_vec(8);
        let xm = Matrix::from_vec(8, 1, x.clone());
        let expect = matmul(&a, &xm);
        let got = matvec(&a, &x);
        for i in 0..15 {
            assert!((got[i] - expect.at(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn thread_override_parsing() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-1"), None);
        assert_eq!(parse_threads("lots"), None);
        assert!(num_threads() >= 1);
    }

    #[test]
    fn sparse_a_short_circuit_correct() {
        let mut rng = Rng::new(7);
        let mut a = Matrix::randn(30, 30, &mut rng);
        for (i, v) in a.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::randn(30, 30, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
    }
}
