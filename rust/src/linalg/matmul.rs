//! Blocked, multi-threaded matmul — the L3 hot path when the PJRT runtime
//! is not in play (native baselines, tests, small shapes).
//!
//! Kernel structure mirrors the Pallas kernel (DESIGN.md §Hardware-
//! Adaptation), now with the memory hierarchy made explicit:
//!
//! * **Register tier:** an MR x LANE accumulator tile per column group —
//!   portable `[f32; LANE]` lanes the compiler lowers to wide SIMD
//!   (f32x8 on AVX2), one B lane load reused MR times.
//! * **Cache tier:** the K loop is blocked into KC-deep panels and the
//!   touched B panel is packed contiguous per NR-wide column strip, so
//!   the inner loops stream from L1/L2 instead of striding `n_dim`
//!   floats between consecutive k rows.
//! * **Thread tier:** rows of C are partitioned across threads, each
//!   writing its disjoint `split_at_mut` strip of C in place (no
//!   per-thread buffer, no merge copy).
//!
//! Exactness discipline: every output element is produced by ONE
//! k-ascending f32 accumulation chain. K-panel boundaries spill the
//! accumulator tile to C and reload it — an exact f32 round-trip — and
//! lanes vectorize across independent output columns, never across k,
//! so results are bit-identical whatever the thread partition or panel
//! split (the repo's standing bit-identity bar: CSR vs packed N:M,
//! native vs sharded, resume). The one documented exception is
//! [`matvec`], which reduces through four f64 partial lanes in a fixed
//! order — deterministic, but not the sequential chain; its callers
//! (PCG, scale re-fitting, Cholesky checks) are tolerance-tested.
//! See EXPERIMENTS.md §Perf for the optimization log.

use super::matrix::Matrix;

/// C = A @ B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// Below this many f32 multiply-adds the product runs single-threaded
/// ([`matmul_into`]) and `matmul_tn` takes the allocation-free strided
/// path. Above it, the transposed copy amortizes: A^T rows become
/// contiguous for the register-blocked kernel and the row partition fans
/// across the thread pool.
const PAR_CUTOFF: usize = 64 * 64 * 64;

/// C = A^T @ B.
///
/// Small products go through [`matmul_tn_strided`] (no A^T is ever
/// materialized); large ones take an explicit transpose + the blocked
/// threaded [`matmul`]. Both accumulate over k in ascending order, so the
/// two paths agree bitwise on finite inputs.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dims: {}x{} vs {}x{}", a.rows, a.cols, b.rows, b.cols);
    if a.rows * a.cols * b.cols <= PAR_CUTOFF {
        return matmul_tn_strided(a, b);
    }
    let at = a.transpose();
    matmul(&at, b)
}

/// Strided kernel for C = A^T @ B: C[i, :] += A[k, i] * B[k, :] over the
/// shared rows k, blocked into KC-deep panels. Each panel's A slab is
/// gathered transposed (contiguous per output row i), then row i of C is
/// kept hot across the whole panel while the panel's B rows are reused
/// for every i — L2-resident instead of sweeping all of C once per k.
/// Per element the accumulation stays a single k-ascending chain
/// (panels ascend, k within a panel ascends), so this is bit-identical
/// to the unblocked rank-1 formulation on finite inputs.
fn matmul_tn_strided(a: &Matrix, b: &Matrix) -> Matrix {
    let n_dim = b.cols;
    let m_dim = a.cols;
    let mut c = Matrix::zeros(m_dim, n_dim);
    let mut apanel = vec![0.0f32; KC * m_dim];
    let mut kb = 0;
    while kb < a.rows {
        let kw = (a.rows - kb).min(KC);
        for k in 0..kw {
            let arow = a.row(kb + k);
            for (i, &v) in arow.iter().enumerate() {
                apanel[i * kw + k] = v;
            }
        }
        for i in 0..m_dim {
            let ap = &apanel[i * kw..i * kw + kw];
            let crow = &mut c.data[i * n_dim..(i + 1) * n_dim];
            for (k, &av) in ap.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[(kb + k) * n_dim..(kb + k) * n_dim + n_dim];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        kb += KC;
    }
    c
}

/// Gram matrix H = X^T X via [`matmul_tn`] (one shared A^T-product
/// implementation instead of the duplicated explicit-transpose pattern),
/// then symmetrized.
pub fn gram(x: &Matrix) -> Matrix {
    let n = x.cols;
    let mut h = matmul_tn(x, x);
    // enforce exact symmetry (floating point drift breaks eigh otherwise)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = 0.5 * (h.at(i, j) + h.at(j, i));
            *h.at_mut(i, j) = v;
            *h.at_mut(j, i) = v;
        }
    }
    h
}

/// y = A @ x for a vector x.
///
/// Reduces through four f64 partial lanes with a fixed
/// `(l0+l1)+(l2+l3)` merge and a sequential tail — deterministic across
/// runs and thread counts, but NOT the same value as a sequential f64
/// chain; callers (PCG, quantizer scale re-fitting) are tolerance-based.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for (r, yv) in y.iter_mut().enumerate() {
        let row = a.row(r);
        let n4 = row.len() / 4 * 4;
        let mut lanes = [0.0f64; 4];
        let mut k = 0;
        while k < n4 {
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane += (row[k + l] as f64) * (x[k + l] as f64);
            }
            k += 4;
        }
        let mut acc = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        for k in n4..row.len() {
            acc += (row[k] as f64) * (x[k] as f64);
        }
        *yv = acc as f32;
    }
    y
}

/// Number of worker threads: the `ALPS_THREADS` env override when set to a
/// positive integer (read once — serve benches pin it for reproducibility
/// on shared CI machines), else cores - 1, at least 1.
pub fn num_threads() -> usize {
    static OVERRIDE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    let ov = OVERRIDE
        .get_or_init(|| std::env::var("ALPS_THREADS").ok().and_then(|v| parse_threads(&v)));
    if let Some(n) = ov {
        return *n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

/// Parse an `ALPS_THREADS` value; `None` for anything non-positive/garbled.
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|&n| n > 0)
}

/// Portable SIMD lane width: `[f32; LANE]` tiles compile to one ymm
/// vector op on AVX2 (and two on 128-bit NEON/SSE) without `std::arch`.
const LANE: usize = 8;
/// Micro-kernel geometry: MR rows of A against an NR-wide strip of B.
/// Per LANE-wide column group the MR x LANE C tile lives in registers
/// across a whole K panel — one B lane load is reused MR times, so the
/// kernel is compute-bound instead of L1-bound.
const MR: usize = 4;
const NR: usize = 64;
/// K-panel depth: the packed B panel is KC x NR f32 (32 KiB) — resident
/// in L2 and streamed through L1 while every MR-row block of A reuses it.
const KC: usize = 128;

/// C += A @ B restricted to C rows [r0, r1), written into the strip `c`
/// (rows r0..r1 of the full C, row-major, `b.cols` wide, pre-zeroed or
/// carrying prior partial sums).
fn matmul_rows(a: &Matrix, b: &Matrix, c: &mut [f32], r0: usize, r1: usize) {
    let k_dim = a.cols;
    let n_dim = b.cols;
    // last row reachable by a full MR-row block from this strip's base
    let r_mr = r0 + (r1 - r0) / MR * MR;
    let mut bpack = vec![0.0f32; KC * NR];
    let mut nb = 0;
    while nb < n_dim {
        let nw = (n_dim - nb).min(NR);
        if nw == NR && r_mr > r0 {
            let mut kb = 0;
            while kb < k_dim {
                let kw = (k_dim - kb).min(KC);
                pack_b(b, kb, kw, nb, &mut bpack);
                let mut r = r0;
                while r + MR <= r1 {
                    microkernel(a, &bpack, c, r, r0, nb, kb, kw, k_dim, n_dim);
                    r += MR;
                }
                kb += KC;
            }
        }
        // row remainder of a full column panel, or the whole strip for
        // the (< NR) column tail
        let scalar_r0 = if nw == NR { r_mr } else { r0 };
        if scalar_r0 < r1 {
            scalar_tail(a, b, c, scalar_r0, r1, r0, nb, nb + nw, k_dim, n_dim);
        }
        nb += NR;
    }
}

/// Copy the B panel rows [kb, kb+kw) x cols [nb, nb+NR) into a
/// contiguous kw x NR buffer: the micro-kernel then streams lane-aligned
/// consecutive rows instead of striding `n_dim` floats per k.
#[inline]
fn pack_b(b: &Matrix, kb: usize, kw: usize, nb: usize, bpack: &mut [f32]) {
    let n_dim = b.cols;
    for k in 0..kw {
        let src = &b.data[(kb + k) * n_dim + nb..(kb + k) * n_dim + nb + NR];
        bpack[k * NR..k * NR + NR].copy_from_slice(src);
    }
}

/// MR x NR panel kernel over one packed K panel. For each LANE-wide
/// column group the MR x LANE C tile is loaded once, accumulated lane-
/// parallel in k-ascending order across the panel, and stored back — an
/// exact f32 spill, so chaining panels preserves each element's single
/// accumulation chain bit-for-bit (lanes span independent columns, never
/// k).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn microkernel(
    a: &Matrix,
    bpack: &[f32],
    c: &mut [f32],
    r: usize,
    r0: usize,
    nb: usize,
    kb: usize,
    kw: usize,
    k_dim: usize,
    n_dim: usize,
) {
    for g in 0..NR / LANE {
        let col = nb + g * LANE;
        let mut acc = [[0.0f32; LANE]; MR];
        for (i, accr) in acc.iter_mut().enumerate() {
            let base = (r + i - r0) * n_dim + col;
            accr.copy_from_slice(&c[base..base + LANE]);
        }
        for k in 0..kw {
            let bl = &bpack[k * NR + g * LANE..k * NR + g * LANE + LANE];
            for (i, accr) in acc.iter_mut().enumerate() {
                let av = a.data[(r + i) * k_dim + kb + k];
                for (accv, &bv) in accr.iter_mut().zip(bl) {
                    *accv += av * bv;
                }
            }
        }
        for (i, accr) in acc.iter().enumerate() {
            let base = (r + i - r0) * n_dim + col;
            c[base..base + LANE].copy_from_slice(accr);
        }
    }
}

/// Scalar fallback for row/column tails: per row an axpy over the
/// selected column range per nonzero A element, k ascending — the same
/// per-element chain as the vector path, so tails and panels agree
/// bitwise on finite inputs.
#[allow(clippy::too_many_arguments)]
fn scalar_tail(
    a: &Matrix,
    b: &Matrix,
    c: &mut [f32],
    r_start: usize,
    r_end: usize,
    r0: usize,
    n_start: usize,
    n_end: usize,
    k_dim: usize,
    n_dim: usize,
) {
    for r in r_start..r_end {
        let arow = &a.data[r * k_dim..(r + 1) * k_dim];
        let crow = &mut c[(r - r0) * n_dim + n_start..(r - r0) * n_dim + n_end];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[k * n_dim + n_start..k * n_dim + n_end];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C = A @ B into a preallocated C (zeroed here).
///
/// Row-partitioned across the thread pool; both the serve decode batch
/// (`[batch, d]`) and the batched prefill (`[prompt, d]`) land here, so a
/// multi-row prefill fans its rows across workers while a single decode
/// row stays on the calling thread (below the threading cutoff). Each
/// worker writes its disjoint `split_at_mut` strip of C in place — no
/// per-thread buffer and no merge copy — and the row partition cannot
/// change the result bits (every element's chain lives in one strip).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.fill(0.0);
    let nt = num_threads().min(a.rows.max(1));
    if nt <= 1 || a.rows * a.cols * b.cols < PAR_CUTOFF {
        matmul_rows(a, b, &mut c.data, 0, a.rows);
        return;
    }
    let rows_per = a.rows.div_ceil(nt);
    let n_dim = b.cols;
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = &mut c.data;
        let mut r0 = 0usize;
        while r0 < a.rows {
            let r1 = (r0 + rows_per).min(a.rows);
            let (strip, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * n_dim);
            rest = tail;
            s.spawn(move || matmul_rows(a, b, strip, r0, r1));
            r0 = r1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                for j in 0..b.cols {
                    c.data[i * b.cols + j] += a.at(i, k) * b.at(k, j);
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3, 4, 5), (1, 1, 1), (7, 13, 2), (16, 16, 16)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
        }
    }

    #[test]
    fn matches_naive_threaded_sizes() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(130, 70, &mut rng);
        let b = Matrix::randn(70, 90, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
    }

    #[test]
    fn lane_block_and_panel_tails_match_naive() {
        // shapes span every remainder class the blocked kernel has:
        // rows % MR, cols vs NR (sub-LANE, mid-panel, and multi-panel
        // tails), and k on / across the KC panel boundary
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, KC - 1, NR - 1),
            (4, KC, NR),
            (5, KC + 5, NR + 3),
            (6, 2 * KC + 7, NR + LANE + 1),
            (MR + 3, 40, 2 * NR + 5),
            (2, 33, 5),
            (9, KC + 1, 3 * NR),
        ] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn thread_partition_is_bitwise_invariant() {
        // threaded and single-threaded matmul_into must agree bitwise:
        // matmul_rows is the per-thread body, and running it over the
        // full row range vs disjoint strips of the same C must produce
        // identical bits regardless of the partition the pool picks
        let mut rng = Rng::new(10);
        let (m, k, n) = (37, KC + 22, 80);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let mut full = vec![0.0f32; m * n];
        matmul_rows(&a, &b, &mut full, 0, m);
        let mut parts = vec![0.0f32; m * n];
        for (r0, r1) in [(0usize, 10usize), (10, 11), (11, 29), (29, m)] {
            matmul_rows(&a, &b, &mut parts[r0 * n..r1 * n], r0, r1);
        }
        assert_eq!(full, parts, "row partition changed result bits");
        // the public entry point (threaded or not at this size) agrees too
        assert_eq!(matmul(&a, &b).data, full);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 20, &mut rng);
        assert!(matmul(&a, &Matrix::identity(20)).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(50, 12, &mut rng);
        let h = gram(&x);
        for i in 0..12 {
            assert!(h.at(i, i) > 0.0);
            for j in 0..12 {
                assert_eq!(h.at(i, j), h.at(j, i));
            }
        }
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(40, 12, &mut rng);
        let b = Matrix::randn(40, 9, &mut rng);
        let direct = matmul(&a.transpose(), &b);
        assert!(matmul_tn(&a, &b).max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn matmul_tn_strided_and_transpose_paths_agree() {
        let mut rng = Rng::new(8);
        // spans the cutoff and the KC panel boundary: small goes strided,
        // large goes transpose+matmul
        for &(rows, k, n) in &[
            (10, 4, 3),
            (64, 64, 64),
            (70, 64, 64),
            (30, 90, 110),
            (KC + 22, 12, 9),
            (2 * KC + 3, 5, 7),
        ] {
            let a = Matrix::randn(rows, k, &mut rng);
            let b = Matrix::randn(rows, n, &mut rng);
            let strided = matmul_tn_strided(&a, &b);
            let transposed = matmul(&a.transpose(), &b);
            // identical k-ascending accumulation order => tight agreement
            assert!(
                strided.max_abs_diff(&transposed) < 1e-5,
                "{rows}x{k} ^T @ {rows}x{n}"
            );
            assert!(matmul_tn(&a, &b).max_abs_diff(&transposed) < 1e-5);
        }
    }

    #[test]
    fn gram_matches_matmul_tn() {
        // gram is matmul_tn(x, x) + exact symmetrization — the shared path
        let mut rng = Rng::new(9);
        for &(rows, n) in &[(50, 12), (80, 66)] {
            let x = Matrix::randn(rows, n, &mut rng);
            let h = gram(&x);
            let tn = matmul_tn(&x, &x);
            assert!(h.max_abs_diff(&tn) < 1e-5, "{rows}x{n}");
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(h.at(i, j), h.at(j, i));
                }
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(15, 8, &mut rng);
        let x: Vec<f32> = rng.gaussian_vec(8);
        let xm = Matrix::from_vec(8, 1, x.clone());
        let expect = matmul(&a, &xm);
        let got = matvec(&a, &x);
        for i in 0..15 {
            assert!((got[i] - expect.at(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_lane_tails_match_f64_reference() {
        // k values straddling the 4-lane boundary, vs a sequential f64 sum
        let mut rng = Rng::new(12);
        for &(m, k) in &[(3, 1), (5, 4), (7, 9), (4, 35)] {
            let a = Matrix::randn(m, k, &mut rng);
            let x: Vec<f32> = rng.gaussian_vec(k);
            let got = matvec(&a, &x);
            for r in 0..m {
                let want: f64 =
                    a.row(r).iter().zip(&x).map(|(w, v)| *w as f64 * *v as f64).sum();
                assert!((got[r] as f64 - want).abs() < 1e-5, "{m}x{k} row {r}");
            }
        }
    }

    #[test]
    fn thread_override_parsing() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-1"), None);
        assert_eq!(parse_threads("lots"), None);
        assert!(num_threads() >= 1);
    }

    #[test]
    fn sparse_a_short_circuit_correct() {
        let mut rng = Rng::new(7);
        let mut a = Matrix::randn(30, 30, &mut rng);
        for (i, v) in a.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::randn(30, 30, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
    }
}
