//! Wire framing: bounded line reads (text protocols) and length-prefixed
//! binary frames (the pruning worker protocol).
//!
//! Both readers share the same robustness contract:
//!
//! * **Bounded memory** — a line longer than `max` bytes is discarded as
//!   it streams in and reported as [`LineRead::TooLong`]; a frame whose
//!   declared length exceeds `max` is a hard error before any payload is
//!   allocated. A malicious peer cannot grow an unbounded buffer.
//! * **Shutdown-aware** — sockets are expected to carry a short read
//!   timeout; every timeout tick re-checks the caller's shutdown flag so
//!   blocked readers terminate promptly ([`LineRead::Shutdown`] /
//!   [`FrameRead::Shutdown`]).
//! * **EOF at a message boundary is clean** ([`LineRead::Eof`] /
//!   [`FrameRead::Eof`]); EOF mid-frame is an error (the peer died mid
//!   message).
//!
//! ## Binary frame layout
//!
//! ```text
//! [b'A'][b'F'][u8 version][u8 tag][u32 payload_len le][payload ...]
//! ```
//!
//! The 2-byte magic catches text-protocol clients (or plain port
//! scanners) talking to a frame endpoint; the version byte rejects
//! incompatible peers before any payload is interpreted. Tags are
//! protocol-specific (see `crate::pruning::wire`).

use anyhow::{bail, Result};
use std::io::{BufRead, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Frame protocol magic + version (bumped on incompatible layout changes).
///
/// Version history:
/// * 1 — initial pruning protocol (SOLVE/RESULT/ERROR/BUSY; SOLVE always
///   carries the precomputed gram).
/// * 2 — distributed pruning v2: SOLVE payloads carry a calibration
///   discriminant (gram *or* raw activations, see `crate::pruning::wire`)
///   and workers emit periodic HEARTBEAT frames while solving.
/// * 3 — dynamic worker membership: a REGISTER frame
///   (`crate::pruning::wire`) lets a worker announce its serve address to
///   a running coordinator's registration endpoint and join the fleet
///   mid-run; the coordinator acks by echoing the frame.
pub const FRAME_MAGIC: [u8; 2] = *b"AF";
pub const FRAME_VERSION: u8 = 3;
/// Fixed frame header size: magic(2) + version(1) + tag(1) + len(4).
pub const FRAME_HEADER: usize = 8;

/// Lazily-registered transport counters (`alps_net_frames_total` /
/// `alps_net_frame_bytes_total`, labelled by direction). Free functions
/// like [`write_frame`] have no struct to park handles on, so they are
/// process-global `OnceLock`s — one registry lock on first use, lock-free
/// after.
fn frame_metrics(dir: &'static str) -> &'static (crate::obs::Counter, crate::obs::Counter) {
    static TX: std::sync::OnceLock<(crate::obs::Counter, crate::obs::Counter)> =
        std::sync::OnceLock::new();
    static RX: std::sync::OnceLock<(crate::obs::Counter, crate::obs::Counter)> =
        std::sync::OnceLock::new();
    let cell = if dir == "tx" { &TX } else { &RX };
    cell.get_or_init(|| {
        let r = crate::obs::global();
        (
            r.counter("alps_net_frames_total", "binary frames by direction", &[("dir", dir)]),
            r.counter(
                "alps_net_frame_bytes_total",
                "binary frame bytes (header + payload) by direction",
                &[("dir", dir)],
            ),
        )
    })
}

/// Outcome of one bounded line read.
pub enum LineRead {
    Line(String),
    TooLong,
    Eof,
    Shutdown,
}

/// Read one `\n`-terminated line, holding at most `max` bytes of it in
/// memory. Oversized lines are discarded as they stream in and reported
/// as [`LineRead::TooLong`]. Read-timeout ticks re-check the shutdown
/// flag so blocked readers terminate promptly.
pub fn read_line_bounded<R: BufRead>(
    r: &mut R,
    max: usize,
    shutdown: &AtomicBool,
) -> std::io::Result<LineRead> {
    read_line_bounded_inner(r, max, shutdown, None)
}

/// [`read_line_bounded`] with a wall-clock deadline: gives up with a
/// `TimedOut` error if no complete line arrives in time. For one-shot
/// query endpoints, where a connected-but-silent client must not pin a
/// handler thread for the life of the server.
pub fn read_line_deadline<R: BufRead>(
    r: &mut R,
    max: usize,
    shutdown: &AtomicBool,
    deadline: Duration,
) -> std::io::Result<LineRead> {
    read_line_bounded_inner(r, max, shutdown, Some(Instant::now() + deadline))
}

fn read_line_bounded_inner<R: BufRead>(
    r: &mut R,
    max: usize,
    shutdown: &AtomicBool,
    deadline: Option<Instant>,
) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut too_long = false;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(LineRead::Shutdown);
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "no line before the read deadline",
                ));
            }
        }
        let (consumed, done) = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                // EOF: a non-empty partial line still counts as a line
                let done = if too_long {
                    LineRead::TooLong
                } else if buf.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
                };
                (0, Some(done))
            } else {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(p) => {
                        if !too_long && buf.len() + p > max {
                            too_long = true;
                        }
                        if !too_long {
                            buf.extend_from_slice(&chunk[..p]);
                        }
                        let done = if too_long {
                            LineRead::TooLong
                        } else {
                            LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
                        };
                        (p + 1, Some(done))
                    }
                    None => {
                        if buf.len() + chunk.len() > max {
                            too_long = true;
                            buf.clear(); // cap memory; the line is rejected
                        } else {
                            buf.extend_from_slice(chunk);
                        }
                        (chunk.len(), None)
                    }
                }
            }
        };
        r.consume(consumed);
        if let Some(l) = done {
            return Ok(l);
        }
    }
}

/// Write one tagged frame (header + payload) and flush. Payloads beyond
/// the u32 length field are rejected up front — a wrapped length would
/// silently desync the stream.
pub fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > u32::MAX as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame payload of {} bytes exceeds the u32 length field", payload.len()),
        ));
    }
    let mut header = [0u8; FRAME_HEADER];
    header[..2].copy_from_slice(&FRAME_MAGIC);
    header[2] = FRAME_VERSION;
    header[3] = tag;
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    let (frames, bytes) = frame_metrics("tx");
    frames.inc();
    bytes.add((FRAME_HEADER + payload.len()) as u64);
    Ok(())
}

/// Outcome of one frame read.
pub enum FrameRead {
    Frame { tag: u8, payload: Vec<u8> },
    /// Clean EOF at a frame boundary (peer closed between messages).
    Eof,
    /// The caller's shutdown flag was raised while waiting.
    Shutdown,
}

/// How a blocking frame read ended below the message layer.
enum Fill {
    Done,
    Eof,
    Shutdown,
}

/// Read exactly `buf.len()` bytes, looping over read-timeout ticks.
/// `eof_ok` permits a clean EOF *before the first byte* (frame boundary);
/// EOF after partial progress is always an error. `idle` bounds how long
/// to wait with no bytes arriving at all (a hung peer) — progress resets
/// the clock. `deadline` bounds the read in wall-clock time regardless of
/// progress — the defence against a peer dribbling one byte per tick to
/// stay under the idle bound forever.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    eof_ok: bool,
    shutdown: Option<&AtomicBool>,
    idle: Option<Duration>,
    deadline: Option<Instant>,
) -> Result<Fill> {
    let mut have = 0usize;
    let mut last_progress = Instant::now();
    while have < buf.len() {
        if let Some(flag) = shutdown {
            if flag.load(Ordering::SeqCst) {
                return Ok(Fill::Shutdown);
            }
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                bail!(
                    "frame read exceeded its deadline ({} of {} bytes)",
                    have,
                    buf.len()
                );
            }
        }
        match r.read(&mut buf[have..]) {
            Ok(0) => {
                if have == 0 && eof_ok {
                    return Ok(Fill::Eof);
                }
                bail!("peer closed mid-frame ({} of {} bytes)", have, buf.len());
            }
            Ok(n) => {
                have += n;
                last_progress = Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if let Some(limit) = idle {
                    if last_progress.elapsed() > limit {
                        bail!("peer idle for {:.1}s mid-read", limit.as_secs_f64());
                    }
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Fill::Done)
}

/// Read one frame. `max` bounds the accepted payload size; `shutdown`
/// (when given) is re-checked on every read-timeout tick; `idle` (when
/// given) fails the read if the peer sends nothing at all for that long —
/// used by the coordinator so a hung worker surfaces as a reroutable
/// error instead of a stuck run.
pub fn read_frame(
    r: &mut impl Read,
    max: usize,
    shutdown: Option<&AtomicBool>,
    idle: Option<Duration>,
) -> Result<FrameRead> {
    read_frame_deadline(r, max, shutdown, idle, None)
}

/// [`read_frame`] with an additional wall-clock bound on the *whole*
/// frame, progress or not. The idle bound alone can be gamed by a peer
/// dribbling one byte per tick; a total deadline cannot. Used by the
/// coordinator's response reads, where a never-completing frame would
/// otherwise pin that worker's in-flight jobs forever.
pub fn read_frame_deadline(
    r: &mut impl Read,
    max: usize,
    shutdown: Option<&AtomicBool>,
    idle: Option<Duration>,
    total: Option<Duration>,
) -> Result<FrameRead> {
    let deadline = total.map(|t| Instant::now() + t);
    let mut header = [0u8; FRAME_HEADER];
    match read_full(r, &mut header, true, shutdown, idle, deadline)? {
        Fill::Eof => return Ok(FrameRead::Eof),
        Fill::Shutdown => return Ok(FrameRead::Shutdown),
        Fill::Done => {}
    }
    if header[..2] != FRAME_MAGIC {
        bail!("bad frame magic {:?} (text client on a frame port?)", &header[..2]);
    }
    if header[2] != FRAME_VERSION {
        bail!("frame version {} unsupported (want {})", header[2], FRAME_VERSION);
    }
    let tag = header[3];
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if len > max {
        bail!("frame of {len} bytes exceeds the {max}-byte limit");
    }
    let mut payload = vec![0u8; len];
    match read_full(r, &mut payload, false, shutdown, idle, deadline)? {
        Fill::Shutdown => Ok(FrameRead::Shutdown),
        // read_full never reports Eof when eof_ok is false (a short read
        // errors there), but a transport layer must not be able to abort
        // the process on a codepath mistake — treat it as a framing error
        Fill::Eof => bail!("connection closed mid-payload"),
        Fill::Done => {
            let (frames, bytes) = frame_metrics("rx");
            frames.inc();
            bytes.add((FRAME_HEADER + payload.len()) as u64);
            Ok(FrameRead::Frame { tag, payload })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufReader, Cursor};

    fn no_shutdown() -> AtomicBool {
        AtomicBool::new(false)
    }

    #[test]
    fn line_read_basic_and_eof_partial() {
        let flag = no_shutdown();
        let mut r = BufReader::new(Cursor::new(b"hello\nworld".to_vec()));
        match read_line_bounded(&mut r, 64, &flag).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "hello"),
            _ => panic!("expected line"),
        }
        // EOF with a non-empty partial line still yields the line
        match read_line_bounded(&mut r, 64, &flag).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "world"),
            _ => panic!("expected partial line"),
        }
        assert!(matches!(read_line_bounded(&mut r, 64, &flag).unwrap(), LineRead::Eof));
    }

    #[test]
    fn line_read_rejects_oversized_with_bounded_memory() {
        let flag = no_shutdown();
        let mut big = vec![b'x'; 10_000];
        big.push(b'\n');
        big.extend_from_slice(b"ok\n");
        let mut r = BufReader::new(Cursor::new(big));
        assert!(matches!(read_line_bounded(&mut r, 16, &flag).unwrap(), LineRead::TooLong));
        // the oversized line was consumed; the stream continues cleanly
        match read_line_bounded(&mut r, 16, &flag).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "ok"),
            _ => panic!("expected line after oversized reject"),
        }
    }

    #[test]
    fn line_deadline_gives_up_on_silent_reader() {
        // a socket that only ever times out must not pin the caller past
        // its deadline (the status endpoint's one-shot query contract)
        struct Silent;
        impl std::io::Read for Silent {
            fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::ErrorKind::WouldBlock.into())
            }
        }
        let flag = no_shutdown();
        let mut r = BufReader::new(Silent);
        let err = read_line_deadline(&mut r, 64, &flag, Duration::from_millis(20))
            .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn line_read_observes_shutdown() {
        let flag = AtomicBool::new(true);
        let mut r = BufReader::new(Cursor::new(b"never read\n".to_vec()));
        assert!(matches!(read_line_bounded(&mut r, 64, &flag).unwrap(), LineRead::Shutdown));
    }

    #[test]
    fn frame_roundtrip_multiple() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"payload one").unwrap();
        write_frame(&mut buf, 9, b"").unwrap();
        write_frame(&mut buf, 1, &[0xFF; 300]).unwrap();
        let mut r = Cursor::new(buf);
        match read_frame(&mut r, 1024, None, None).unwrap() {
            FrameRead::Frame { tag, payload } => {
                assert_eq!(tag, 7);
                assert_eq!(payload, b"payload one");
            }
            _ => panic!("expected frame"),
        }
        match read_frame(&mut r, 1024, None, None).unwrap() {
            FrameRead::Frame { tag, payload } => {
                assert_eq!(tag, 9);
                assert!(payload.is_empty());
            }
            _ => panic!("expected empty frame"),
        }
        match read_frame(&mut r, 1024, None, None).unwrap() {
            FrameRead::Frame { tag, payload } => {
                assert_eq!(tag, 1);
                assert_eq!(payload.len(), 300);
            }
            _ => panic!("expected frame"),
        }
        assert!(matches!(read_frame(&mut r, 1024, None, None).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn frame_rejects_bad_magic_version_and_oversize() {
        // wrong magic
        let mut r = Cursor::new(b"GET /healthz\r\n\r\n".to_vec());
        let err = read_frame(&mut r, 1024, None, None).unwrap_err().to_string();
        assert!(err.contains("bad frame magic"), "{err}");
        // wrong version
        let mut bad = Vec::new();
        write_frame(&mut bad, 1, b"x").unwrap();
        bad[2] = 99;
        let err = read_frame(&mut Cursor::new(bad), 1024, None, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("version 99"), "{err}");
        // declared length over the cap: rejected before allocation
        let mut big = Vec::new();
        write_frame(&mut big, 1, &vec![0u8; 64]).unwrap();
        let err = read_frame(&mut Cursor::new(big), 16, None, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn frame_eof_mid_payload_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, &[1, 2, 3, 4]).unwrap();
        buf.truncate(FRAME_HEADER + 2); // cut the payload short
        let err = read_frame(&mut Cursor::new(buf), 1024, None, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mid-frame"), "{err}");
    }

    #[test]
    fn dribbled_frame_trips_the_total_deadline() {
        // one byte per read keeps the idle clock happy forever; only the
        // wall-clock deadline can end a never-completing frame
        struct Dribble {
            frame: Vec<u8>,
            pos: usize,
        }
        impl std::io::Read for Dribble {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                std::thread::sleep(Duration::from_millis(1));
                // never run out: repeat the last payload byte forever
                let b = *self.frame.get(self.pos).unwrap_or(&0);
                self.pos += 1;
                buf[0] = b;
                Ok(1)
            }
        }
        // a valid header declaring a payload the peer will never finish
        let mut frame = Vec::new();
        write_frame(&mut frame, 2, &[0u8; 8]).unwrap();
        frame[4..8].copy_from_slice(&(1u32 << 20).to_le_bytes());
        frame.truncate(FRAME_HEADER + 4);
        let mut r = Dribble { frame, pos: 0 };
        let err = read_frame_deadline(
            &mut r,
            2 << 20,
            None,
            Some(Duration::from_secs(60)),
            Some(Duration::from_millis(50)),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("deadline"), "{err}");
    }

    #[test]
    fn frame_observes_shutdown_flag() {
        let flag = AtomicBool::new(true);
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, b"x").unwrap();
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), 1024, Some(&flag), None).unwrap(),
            FrameRead::Shutdown
        ));
    }
}
