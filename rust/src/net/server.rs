//! Generic threaded TCP accept loop — the connection plumbing shared by
//! the serve front-end, the pruning worker, and the status endpoint.
//!
//! [`NetServer::run`] owns the lifecycle that `serve/tcp.rs` used to
//! implement inline:
//!
//! * one scoped thread per accepted connection, handed to a
//!   [`ConnHandler`];
//! * a connection cap ([`ServerConfig::max_conns`]): over-cap connections
//!   go to [`ConnHandler::refuse`] on a separate bounded refusal pool
//!   ([`ServerConfig::max_refusals`]), and a connect flood beyond that
//!   pool is dropped outright so the cap actually bounds server
//!   resources;
//! * graceful shutdown: [`NetServer::shutdown`] raises a flag every
//!   handler can poll (via [`NetServer::shutdown_flag`], designed to pair
//!   with the timeout-tick readers in [`crate::net::framing`]) and pokes
//!   the blocking accept loop with a loopback connection so it observes
//!   the flag; `run` returns only after every connection thread has been
//!   joined — the drain.
//!
//! The server itself never reads or writes client sockets (except the
//! default refusal line); protocol logic lives entirely in the handler.

use super::lock;
use anyhow::{Context as _, Result};
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Suggested read timeout for handler sockets: how quickly an idle reader
/// notices a server shutdown.
pub const READ_POLL: Duration = Duration::from_millis(200);
/// Suggested write timeout: a client that stops reading (full TCP window)
/// fails its handler instead of wedging the drain join at shutdown.
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept-loop configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Concurrent connection cap; excess connections are refused.
    pub max_conns: usize,
    /// Concurrent refusal threads; connections beyond this during a
    /// connect flood are dropped without ceremony.
    pub max_refusals: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_conns: 64, max_refusals: 8 }
    }
}

/// Finish a refusal reply: half-close the write side, then drain
/// pipelined inbound data until EOF or a deadline — closing with unread
/// data still buffered can RST the just-written reply away before the
/// peer reads it. The drain is sized for real pipelines (a refused
/// pruning coordinator may already have megabytes of solve frames in
/// flight), while the deadline keeps a malicious firehose from pinning a
/// refusal thread. The caller must have set a short read timeout so a
/// silent peer cannot stall the thread either. Shared by the default
/// [`ConnHandler::refuse`] and the protocol-specific overrides (serve
/// healthz, worker BUSY frame).
pub fn finish_refusal(stream: &TcpStream) {
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut st = stream;
    let mut sink = [0u8; 64 * 1024];
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    while std::time::Instant::now() < deadline {
        match std::io::Read::read(&mut st, &mut sink) {
            Ok(0) | Err(_) => break, // EOF, timeout, or reset: done either way
            Ok(_) => continue,
        }
    }
}

/// Write a minimal one-shot `HTTP/1.1 200 OK` response with the given
/// content type (the shape every probe endpoint in this crate serves:
/// JSON snapshots and the Prometheus `/metrics` text).
pub fn write_http_response(
    w: &mut impl std::io::Write,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: {}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        content_type,
        body.len(),
        body
    )
}

/// [`write_http_response`] specialized to `application/json`.
pub fn write_http_json(w: &mut impl std::io::Write, body: &str) -> std::io::Result<()> {
    write_http_response(w, "application/json", body)
}

/// Answer a `GET` probe on a line-protocol connection: drain the request
/// headers first (closing with unread inbound data buffered can RST the
/// response away), then write the reply. Shared by the serve healthz,
/// the pruning status endpoint, and every `/metrics` exposition.
pub fn respond_http<R: std::io::BufRead>(
    reader: &mut R,
    stream: &mut impl std::io::Write,
    max_line: usize,
    shutdown: &AtomicBool,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    loop {
        match crate::net::framing::read_line_bounded(reader, max_line, shutdown)? {
            crate::net::framing::LineRead::Line(h) if !h.trim().is_empty() => continue,
            _ => break,
        }
    }
    write_http_response(stream, content_type, body)
}

/// [`respond_http`] specialized to `application/json`.
pub fn respond_http_json<R: std::io::BufRead>(
    reader: &mut R,
    stream: &mut impl std::io::Write,
    max_line: usize,
    shutdown: &AtomicBool,
    body: &str,
) -> std::io::Result<()> {
    respond_http(reader, stream, max_line, shutdown, "application/json", body)
}

/// Path of an HTTP request line (`"GET /metrics HTTP/1.1"` ->
/// `"/metrics"`). Query strings are dropped; a malformed line yields
/// `"/"` so callers fall through to their default probe response.
pub fn request_path(request_line: &str) -> &str {
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    path.split('?').next().unwrap_or("/")
}

/// Process-global accept-loop counters, shared by every [`NetServer`] in
/// the process (`accepted`, `closed`, `refused`; live connections are
/// `accepted - closed` on the scraper side, which composes across
/// servers where a per-server gauge would stomp).
fn conn_metrics() -> &'static (crate::obs::Counter, crate::obs::Counter, crate::obs::Counter) {
    static M: std::sync::OnceLock<(
        crate::obs::Counter,
        crate::obs::Counter,
        crate::obs::Counter,
    )> = std::sync::OnceLock::new();
    M.get_or_init(|| {
        let r = crate::obs::global();
        (
            r.counter("alps_net_connections_total", "connections handed to a handler", &[]),
            r.counter("alps_net_connections_closed_total", "handler connections finished", &[]),
            r.counter("alps_net_refusals_total", "connections refused over the cap", &[]),
        )
    })
}

/// Per-connection protocol logic plugged into [`NetServer::run`].
pub trait ConnHandler: Sync {
    /// Serve one accepted connection until it closes. The handler is
    /// responsible for socket timeouts (pair [`READ_POLL`] reads with the
    /// server's [`NetServer::shutdown_flag`] so shutdown drains promptly).
    fn handle(&self, stream: TcpStream) -> Result<()>;

    /// Answer an over-cap connection. The default writes one refusal line,
    /// half-closes, and briefly drains pipelined input — closing with
    /// unread inbound data buffered can RST the refusal away before the
    /// client reads it. Protocol-specific servers override this (the
    /// serve front-end still answers health probes at capacity, the
    /// worker replies with a binary busy frame).
    fn refuse(&self, stream: TcpStream, cap: usize) {
        let mut st = stream;
        let _ = st.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = st.set_write_timeout(Some(WRITE_TIMEOUT));
        let _ = writeln!(st, "err - connection limit reached ({cap})");
        finish_refusal(&st);
    }
}

/// A threaded multi-connection TCP server: accept loop + connection cap +
/// graceful shutdown drain. One instance serves one listener at a time.
pub struct NetServer {
    cfg: ServerConfig,
    shutdown: AtomicBool,
    conns: AtomicUsize,
    refusing: AtomicUsize,
    accepted: AtomicUsize,
    /// Bound address, recorded by `run` so `shutdown` can poke the
    /// blocking accept loop.
    addr: Mutex<Option<SocketAddr>>,
}

impl NetServer {
    pub fn new(cfg: ServerConfig) -> NetServer {
        let cfg = ServerConfig { max_conns: cfg.max_conns.max(1), ..cfg };
        NetServer {
            cfg,
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            refusing: AtomicUsize::new(0),
            accepted: AtomicUsize::new(0),
            addr: Mutex::new(None),
        }
    }

    /// Currently live connection handlers.
    pub fn connections(&self) -> usize {
        self.conns.load(Ordering::SeqCst)
    }

    /// Connections handed to the handler over this server's lifetime
    /// (refusals excluded). Lets callers observe connection churn — e.g.
    /// the sharded-pruning tests proving the coordinator's persistent
    /// pool reuses connections across blocks instead of redialing.
    pub fn total_accepted(&self) -> usize {
        self.accepted.load(Ordering::SeqCst)
    }

    /// The configured connection cap.
    pub fn max_conns(&self) -> usize {
        self.cfg.max_conns
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// The raw flag, for the timeout-tick readers in
    /// [`crate::net::framing`].
    pub fn shutdown_flag(&self) -> &AtomicBool {
        &self.shutdown
    }

    /// Flag shutdown and poke the blocking accept loop with a dummy
    /// connection so it observes the flag. A wildcard bind (0.0.0.0 / ::)
    /// is not a connectable address, so the poke targets loopback on the
    /// same port. Best-effort: if the connect fails anyway, the accept
    /// loop still exits on the next inbound connection attempt.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let addr = *lock(&self.addr);
        if let Some(mut addr) = addr {
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr {
                    SocketAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }

    /// Serve connections on `listener` until [`NetServer::shutdown`] is
    /// called (by a handler or another thread). Returns after all
    /// connection threads have been joined; the shutdown flag is always
    /// raised on return so handler loops and companion threads can rely
    /// on it.
    pub fn run<H: ConnHandler>(&self, listener: TcpListener, handler: &H) -> Result<()> {
        let addr = listener.local_addr().context("reading bound address")?;
        *lock(&self.addr) = Some(addr);
        // shutdown() may have raced ahead of this thread: it either saw
        // the address just stored (and pokes the accept loop) or ran
        // before our lock (mutex ordering then guarantees we see its flag
        // here) — never enter a poke-less blocking accept
        if self.is_shutdown() {
            return Ok(());
        }
        std::thread::scope(|s| {
            for stream in listener.incoming() {
                if self.is_shutdown() {
                    break;
                }
                let stream = match stream {
                    Ok(st) => st,
                    Err(e) => {
                        eprintln!("[net] accept error: {e}");
                        continue;
                    }
                };
                if self.conns.load(Ordering::SeqCst) >= self.cfg.max_conns {
                    // refusal drains briefly; keep the accept loop free by
                    // doing it off-thread, with the refusal pool itself
                    // capped so a connect flood can't mint unbounded threads
                    conn_metrics().2.inc();
                    if self.refusing.load(Ordering::SeqCst) < self.cfg.max_refusals {
                        self.refusing.fetch_add(1, Ordering::SeqCst);
                        s.spawn(move || {
                            handler.refuse(stream, self.cfg.max_conns);
                            self.refusing.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    continue; // beyond the refusal pool: dropped without ceremony
                }
                // incremented here (not in the spawned thread) so the cap
                // check on the next accept already sees this connection
                self.conns.fetch_add(1, Ordering::SeqCst);
                self.accepted.fetch_add(1, Ordering::SeqCst);
                conn_metrics().0.inc();
                s.spawn(move || {
                    if let Err(e) = handler.handle(stream) {
                        eprintln!("[net] connection error: {e}");
                    }
                    self.conns.fetch_sub(1, Ordering::SeqCst);
                    conn_metrics().1.inc();
                });
            }
            // accept loop done: raise the flag so handler read loops (and
            // any companion threads polling it) terminate, then the scope
            // join drains every in-flight connection
            self.shutdown.store(true, Ordering::SeqCst);
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{read_line_bounded, LineRead};
    use std::io::{BufRead, BufReader, Read, Write};

    /// Echoes each line back prefixed with `echo `; `quit` shuts the
    /// server down.
    struct EchoHandler<'a> {
        net: &'a NetServer,
    }

    impl ConnHandler for EchoHandler<'_> {
        fn handle(&self, stream: TcpStream) -> Result<()> {
            stream.set_read_timeout(Some(READ_POLL))?;
            stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut stream = stream;
            loop {
                match read_line_bounded(&mut reader, 1024, self.net.shutdown_flag())? {
                    LineRead::Line(l) if l.trim() == "quit" => {
                        writeln!(stream, "bye")?;
                        self.net.shutdown();
                        return Ok(());
                    }
                    LineRead::Line(l) => writeln!(stream, "echo {l}")?,
                    _ => return Ok(()),
                }
            }
        }
    }

    fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        (BufReader::new(s.try_clone().unwrap()), s)
    }

    #[test]
    fn serves_concurrent_connections_and_drains_on_shutdown() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let net = NetServer::new(ServerConfig::default());
        std::thread::scope(|s| {
            let server = s.spawn(|| net.run(listener, &EchoHandler { net: &net }));
            let mut clients: Vec<_> = (0..3).map(|_| connect(addr)).collect();
            for (i, (r, w)) in clients.iter_mut().enumerate() {
                writeln!(w, "hello {i}").unwrap();
                let mut l = String::new();
                r.read_line(&mut l).unwrap();
                assert_eq!(l.trim(), format!("echo hello {i}"));
            }
            let (mut r, mut w) = connect(addr);
            writeln!(w, "quit").unwrap();
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            assert_eq!(l.trim(), "bye");
            server.join().unwrap().unwrap();
            assert!(net.is_shutdown());
            assert_eq!(net.connections(), 0);
        });
    }

    #[test]
    fn over_cap_connection_gets_default_refusal() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let net = NetServer::new(ServerConfig { max_conns: 1, ..Default::default() });
        std::thread::scope(|s| {
            let server = s.spawn(|| net.run(listener, &EchoHandler { net: &net }));
            // first client occupies the only slot
            let (mut r1, mut w1) = connect(addr);
            writeln!(w1, "hi").unwrap();
            let mut l = String::new();
            r1.read_line(&mut l).unwrap();
            assert_eq!(l.trim(), "echo hi");
            // second client is refused with the default error line
            let (mut r2, _w2) = connect(addr);
            let mut resp = String::new();
            r2.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("err - connection limit reached (1)"), "got: {resp}");
            writeln!(w1, "quit").unwrap();
            server.join().unwrap().unwrap();
        });
    }
}
