//! `net` — the shared TCP transport layer.
//!
//! Extracted from the serve front-end (`serve/tcp.rs`) so every network
//! endpoint in the crate builds on one audited implementation instead of
//! re-growing its own accept loop and framing code. Three consumers:
//!
//! * the **serve front-end** (`crate::serve::tcp`) — line protocol over
//!   [`framing::read_line_bounded`], connections managed by
//!   [`server::NetServer`];
//! * the **pruning worker** (`crate::pruning::worker`) — length-prefixed
//!   binary frames ([`framing::read_frame`] / [`framing::write_frame`])
//!   carrying serialized layer problems (`crate::pruning::wire`);
//! * the **status endpoint** (`crate::pruning::status`) — one-shot
//!   line/HTTP queries answering with a progress snapshot.
//!
//! Split of responsibilities:
//!
//! * [`framing`] — message boundaries: bounded `\n`-terminated line reads
//!   and `[magic][version][tag][len][payload]` binary frames. Both are
//!   shutdown-aware (read-timeout ticks re-check a caller flag) and hold
//!   bounded memory against malicious peers.
//! * [`server`] — connection lifecycle: per-connection threads behind a
//!   connection cap, a bounded refusal pool for over-cap clients, and a
//!   graceful shutdown drain (flag + accept-loop poke + scoped join).
//!
//! Protocol logic stays with the endpoints; this layer never interprets
//! payloads.
//!
//! The transport reports into the [`crate::obs`] registry: frame/byte
//! counters by direction in [`framing`] (`alps_net_frames_total`,
//! `alps_net_frame_bytes_total`) and accept/close/refusal counters in
//! [`server`] (`alps_net_connections_total` & co.) — recording is
//! lock-free, so the counters cost nothing observable on the wire path.
//! [`server`] also hosts the shared one-shot HTTP reply helpers
//! ([`server::respond_http`] / [`server::write_http_response`]) that the
//! `GET /healthz`, `GET /status`, and `GET /metrics` probes are built on.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod framing;
pub mod server;

pub use framing::{read_frame, read_line_bounded, write_frame, FrameRead, LineRead};
pub use server::{ConnHandler, NetServer, ServerConfig, READ_POLL, WRITE_TIMEOUT};

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: a panicked handler thread must not take the
/// whole server down with it.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // lint:allow(lock) this IS the poison-tolerant wrapper every other module must call
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
