//! The pruning target: a decoder-only transformer with rust-native
//! inference (perplexity/zero-shot eval) and binary weight IO shared with
//! the build-time python trainer.
//!
//! Decode-time weight access goes through the [`DecodeOps`] seam: the
//! same [`Decoder`] runs over dense matrices ([`DenseOps`]), the CSR
//! [`SparseModel`], or the packed N:M [`crate::sparse::NmModel`] — the
//! backends are interchangeable and (for the two sparse ones)
//! bit-identical, so exactness tests diff their outputs directly.

pub mod sparse_infer;
pub mod transformer;
pub mod weights;

pub use sparse_infer::SparseModel;
pub use transformer::{BlockInputs, DecodeOps, Decoder, DenseOps, KvCache, Model};
pub use weights::Weights;

/// Names of the prunable matrices of block `i`, with their activation
/// group (matrices in the same group consume identical inputs X, so the
/// coordinator computes one gram matrix per group).
pub fn prunable_layers(i: usize) -> Vec<(String, ActivationTap)> {
    let p = format!("blocks.{i}.");
    vec![
        (format!("{p}attn.wq"), ActivationTap::AttnIn),
        (format!("{p}attn.wk"), ActivationTap::AttnIn),
        (format!("{p}attn.wv"), ActivationTap::AttnIn),
        (format!("{p}attn.wo"), ActivationTap::AttnOut),
        (format!("{p}mlp.w1"), ActivationTap::MlpIn),
        (format!("{p}mlp.w2"), ActivationTap::MlpHidden),
    ]
}

/// Which intermediate activation feeds a prunable matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActivationTap {
    /// Post-LN1 input (feeds wq / wk / wv — one shared gram).
    AttnIn,
    /// Attention mix output (feeds wo).
    AttnOut,
    /// Post-LN2 input (feeds mlp.w1).
    MlpIn,
    /// GELU hidden activations (feeds mlp.w2).
    MlpHidden,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_prunable_layers_per_block() {
        let layers = prunable_layers(3);
        assert_eq!(layers.len(), 6);
        assert!(layers[0].0.starts_with("blocks.3."));
        // wq/wk/wv share the AttnIn tap
        assert_eq!(layers[0].1, ActivationTap::AttnIn);
        assert_eq!(layers[1].1, ActivationTap::AttnIn);
        assert_eq!(layers[2].1, ActivationTap::AttnIn);
        assert_eq!(layers[3].1, ActivationTap::AttnOut);
    }
}
