//! ALPSMDL1 binary weight IO — the format written by
//! `python/compile/pretrain.py` (see its docstring for the layout).

use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// A named tensor (1-D or 2-D).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// View as a matrix (2-D tensors only).
    pub fn as_matrix(&self) -> Result<Matrix> {
        if self.shape.len() != 2 {
            bail!("tensor is {}-D, expected 2-D", self.shape.len());
        }
        Ok(Matrix::from_vec(self.shape[0], self.shape[1], self.data.clone()))
    }

    pub fn from_matrix(m: &Matrix) -> Tensor {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

/// Ordered named tensors (order preserved for the model_fwd artifact's
/// positional parameters).
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub order: Vec<String>,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        self.get(name)?.as_matrix()
    }

    pub fn vector(&self, name: &str) -> Result<&[f32]> {
        let t = self.get(name)?;
        if t.shape.len() != 1 {
            bail!("tensor '{name}' is {}-D, expected 1-D", t.shape.len());
        }
        Ok(&t.data)
    }

    pub fn set_matrix(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let t = self
            .tensors
            .get_mut(name)
            .with_context(|| format!("missing tensor '{name}'"))?;
        if t.shape != [m.rows, m.cols] {
            bail!("shape mismatch for '{name}': {:?} vs {}x{}", t.shape, m.rows, m.cols);
        }
        t.data = m.data.clone();
        Ok(())
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(Tensor::numel).sum()
    }

    /// Overall fraction of exactly-zero weights in the named matrices.
    pub fn sparsity_of(&self, names: &[String]) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for n in names {
            if let Some(t) = self.tensors.get(n) {
                zeros += t.data.iter().filter(|v| **v == 0.0).count();
                total += t.data.len();
            }
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }

    /// Load from the ALPSMDL1 binary format.
    pub fn load(path: &Path) -> Result<Weights> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening weights {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"ALPSMDL1" {
            bail!("bad magic in {path:?}: {magic:?}");
        }
        let n_tensors = read_u32(&mut f)? as usize;
        let mut w = Weights::default();
        for _ in 0..n_tensors {
            let name = read_string(&mut f)?;
            let ndim = read_u32(&mut f)? as usize;
            if ndim > 4 {
                bail!("tensor '{name}' has suspicious ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut buf = vec![0u8; numel * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            w.order.push(name.clone());
            w.tensors.insert(name, Tensor { shape, data });
        }
        Ok(w)
    }

    /// Save in the same format (pruned-model checkpoints).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(b"ALPSMDL1")?;
        f.write_all(&(self.order.len() as u32).to_le_bytes())?;
        for name in &self.order {
            let t = self.get(name)?;
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let mut buf = Vec::with_capacity(t.data.len() * 4);
            for v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_string(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 4096 {
        bail!("suspicious string length {len}");
    }
    let mut b = vec![0u8; len];
    f.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_weights() -> Weights {
        let mut w = Weights::default();
        w.order.push("a".into());
        w.tensors.insert(
            "a".into(),
            Tensor { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 0.] },
        );
        w.order.push("b.g".into());
        w.tensors.insert("b.g".into(), Tensor { shape: vec![4], data: vec![1.; 4] });
        w
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("alps_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        let w = sample_weights();
        w.save(&p).unwrap();
        let r = Weights::load(&p).unwrap();
        assert_eq!(r.order, w.order);
        assert_eq!(r.tensors, w.tensors);
    }

    #[test]
    fn matrix_and_vector_accessors() {
        let w = sample_weights();
        let m = w.matrix("a").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
        assert_eq!(w.vector("b.g").unwrap(), &[1.0; 4]);
        assert!(w.matrix("b.g").is_err());
        assert!(w.vector("a").is_err());
        assert!(w.get("missing").is_err());
    }

    #[test]
    fn set_matrix_validates_shape() {
        let mut w = sample_weights();
        assert!(w.set_matrix("a", &Matrix::zeros(2, 3)).is_ok());
        assert!(w.set_matrix("a", &Matrix::zeros(3, 2)).is_err());
    }

    #[test]
    fn sparsity_computation() {
        let w = sample_weights();
        let s = w.sparsity_of(&["a".to_string()]);
        assert!((s - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("alps_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC____").unwrap();
        assert!(Weights::load(&p).is_err());
    }

    #[test]
    fn total_params() {
        assert_eq!(sample_weights().total_params(), 10);
    }
}
