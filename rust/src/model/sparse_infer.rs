//! Sparse inference: run the transformer forward with the pruned weight
//! matrices held in CSR form, skipping the zeros the pruner created —
//! the deployment payoff the paper's intro motivates ("sparsity reduces
//! the storage and can accelerate the inference").
//!
//! Numerically identical to the dense path (tests pin exactness); speed
//! crosses over once prunable-matrix density drops below the CSR
//! bookkeeping overhead (~50% on this CPU; see bench_perf_hotpath).

use super::transformer::{DecodeOps, Model};
use crate::linalg::{Csr, Matrix};
use anyhow::Result;
use std::collections::HashMap;

/// A model with CSR-converted prunable matrices.
pub struct SparseModel<'m> {
    pub model: &'m Model,
    csr: HashMap<String, Csr>,
}

impl<'m> SparseModel<'m> {
    /// Convert every prunable matrix to CSR (dense tensors untouched).
    pub fn from_model(model: &'m Model) -> Result<Self> {
        let mut csr = HashMap::new();
        for name in model.prunable_names() {
            let w = model.weights.matrix(&name)?;
            csr.insert(name, Csr::from_dense(&w));
        }
        Ok(SparseModel { model, csr })
    }

    /// Weighted mean density over the prunable matrices.
    pub fn density(&self) -> f64 {
        let (mut nnz, mut total) = (0usize, 0usize);
        for c in self.csr.values() {
            nnz += c.nnz();
            total += c.rows * c.cols;
        }
        nnz as f64 / total.max(1) as f64
    }

    /// Memory footprint of the sparse prunable weights in bytes (f32
    /// values + u32 col indices + u32 row pointers), vs dense f32.
    pub fn bytes_sparse_vs_dense(&self) -> (usize, usize) {
        let mut sparse = 0usize;
        let mut dense = 0usize;
        for c in self.csr.values() {
            sparse += c.bytes();
            dense += c.rows * c.cols * 4;
        }
        (sparse, dense)
    }

    fn mm(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        Ok(self
            .csr
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no CSR for '{name}'"))?
            .left_matmul(x))
    }

    /// Per-position next-token NLL — sparse mirror of `Model::nll`.
    pub fn nll(&self, ids: &[u16]) -> Result<Vec<f64>> {
        let m = self.model;
        let cfg = &m.cfg;
        let s = ids.len();
        anyhow::ensure!(s <= cfg.seq_len, "sequence too long");
        let emb = m.weights.matrix("tok_emb")?;
        let pos = m.weights.matrix("pos_emb")?;
        let d = cfg.d_model;
        let mut x = Matrix::zeros(s, d);
        for (t, &id) in ids.iter().enumerate() {
            anyhow::ensure!((id as usize) < cfg.vocab, "token out of vocab");
            let erow = emb.row(id as usize);
            let prow = pos.row(t);
            let xrow = x.row_mut(t);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
        for b in 0..cfg.n_layers {
            let p = format!("blocks.{b}.");
            let h = layer_norm(
                &x,
                m.weights.vector(&format!("{p}ln1.g"))?,
                m.weights.vector(&format!("{p}ln1.b"))?,
            );
            let attn_out = self.attention(&h, b)?;
            x = x.add(&attn_out);
            let h2 = layer_norm(
                &x,
                m.weights.vector(&format!("{p}ln2.g"))?,
                m.weights.vector(&format!("{p}ln2.b"))?,
            );
            let mut hidden = self.mm(&format!("{p}mlp.w1"), &h2)?;
            hidden.data.iter_mut().for_each(|v| *v = gelu(*v));
            x = x.add(&self.mm(&format!("{p}mlp.w2"), &hidden)?);
        }
        let hfinal = layer_norm(&x, m.weights.vector("ln_f.g")?, m.weights.vector("ln_f.b")?);
        let logits = crate::linalg::matmul::matmul(&hfinal, &emb.transpose());
        let mut out = Vec::with_capacity(s - 1);
        for t in 0..s - 1 {
            let row = logits.row(t);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse: f64 =
                row.iter().map(|v| ((*v as f64) - max).exp()).sum::<f64>().ln() + max;
            out.push(lse - row[ids[t + 1] as usize] as f64);
        }
        Ok(out)
    }

    fn attention(&self, x: &Matrix, block: usize) -> Result<Matrix> {
        let m = self.model;
        let p = format!("blocks.{block}.attn.");
        let q = self.mm(&format!("{p}wq"), x)?;
        let k = self.mm(&format!("{p}wk"), x)?;
        let v = self.mm(&format!("{p}wv"), x)?;
        let (s, d) = (x.rows, x.cols);
        let heads = m.cfg.n_heads;
        let hd = m.cfg.head_dim();
        let mut mix = Matrix::zeros(s, d);
        let scale = 1.0 / (hd as f32).sqrt();
        for head in 0..heads {
            let off = head * hd;
            let mut scores = Matrix::zeros(s, s);
            for i in 0..s {
                let qi = &q.row(i)[off..off + hd];
                for j in 0..=i {
                    let kj = &k.row(j)[off..off + hd];
                    let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                    *scores.at_mut(i, j) = dot * scale;
                }
                for j in (i + 1)..s {
                    *scores.at_mut(i, j) = -1e30;
                }
            }
            softmax_rows(&mut scores);
            for i in 0..s {
                let srow = scores.row(i);
                let orow = mix.row_mut(i);
                for j in 0..=i {
                    let sv = srow[j];
                    if sv == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(j)[off..off + hd];
                    for (t, vv) in vrow.iter().enumerate() {
                        orow[off + t] += sv * vv;
                    }
                }
            }
        }
        self.mm(&format!("{p}wo"), &mix)
    }
}

/// CSR decode backend: the same incremental KV-cache decode as the dense
/// path, with every prunable matmul routed through the sparse kernels —
/// the single-row kernel for unbatched decode, `left_matmul` for batched
/// decode steps and the multi-row `Decoder::prefill_batch` passes.
impl DecodeOps for SparseModel<'_> {
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        if x.rows == 1 {
            let c = self
                .csr
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("no CSR for '{name}'"))?;
            return Ok(Matrix::from_vec(1, c.cols, c.row_matvec(x.row(0))));
        }
        self.mm(name, x)
    }
}

// local mirrors of the dense helpers (kept private in transformer.rs)
fn layer_norm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let eps = 1e-5f32;
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / x.cols as f32;
        let var: f32 =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = (row[c] - mean) * inv * g[c] + b[c];
        }
    }
    out
}

fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::model::transformer::testutil::random_model;

    #[test]
    fn sparse_matches_dense_exactly_on_dense_model() {
        let m = random_model(0);
        let sm = SparseModel::from_model(&m).unwrap();
        let ids = vec![1u16, 5, 9, 3, 7];
        let dense = m.nll(&ids).unwrap();
        let sparse = sm.nll(&ids).unwrap();
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_matches_dense_on_pruned_model() {
        let mut m = random_model(1);
        // zero out 70% of one matrix
        let name = "blocks.0.mlp.w1";
        let w = m.weights.matrix(name).unwrap();
        let pruned = crate::pruning::projection::topk_project(&w, w.data.len() * 3 / 10);
        m.weights.set_matrix(name, &pruned).unwrap();
        let sm = SparseModel::from_model(&m).unwrap();
        let ids = vec![2u16, 4, 6, 8];
        let dense = m.nll(&ids).unwrap();
        let sparse = sm.nll(&ids).unwrap();
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!(sm.density() < 1.0);
    }

    #[test]
    fn memory_accounting() {
        let mut m = random_model(2);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let pruned = crate::pruning::projection::topk_project(&w, w.data.len() / 10);
            m.weights.set_matrix(&name, &pruned).unwrap();
        }
        let sm = SparseModel::from_model(&m).unwrap();
        let (sparse, dense) = sm.bytes_sparse_vs_dense();
        assert!(sparse < dense, "sparse {sparse} !< dense {dense}");
        assert!((sm.density() - 0.1).abs() < 0.01);
    }

    #[test]
    fn sparse_kv_decode_matches_full_forward() {
        // CSR-path incremental decode pins against the dense full-prefix
        // forward on a pruned model (both are exact on the same weights)
        use crate::model::transformer::Decoder;
        let mut m = random_model(4);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let pruned = crate::pruning::projection::topk_project(&w, w.data.len() * 3 / 10);
            m.weights.set_matrix(&name, &pruned).unwrap();
        }
        let sm = SparseModel::from_model(&m).unwrap();
        assert!(sm.density() < 0.35);
        let dec = Decoder::new(&m, sm).unwrap();
        let ids = [2u16, 7, 1, 9, 4, 3];
        let full = m.logits(&ids).unwrap();
        let mut cache = dec.new_cache();
        for (t, &tok) in ids.iter().enumerate() {
            let logits = dec.step(&mut cache, tok).unwrap();
            for c in 0..m.cfg.vocab {
                assert!(
                    (logits[c] - full.at(t, c)).abs() < 1e-4,
                    "t={t} c={c}: {} vs {}",
                    logits[c],
                    full.at(t, c)
                );
            }
        }
    }

    #[test]
    fn sparse_prefill_batch_matches_stepwise_and_dense() {
        // CSR-path batched prefill pins against both the CSR token-by-token
        // prefill and the dense full-prefix forward on a pruned model
        use crate::model::transformer::{DenseOps, Decoder};
        let mut m = random_model(5);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let pruned = crate::pruning::projection::topk_project(&w, w.data.len() * 3 / 10);
            m.weights.set_matrix(&name, &pruned).unwrap();
        }
        let sm = SparseModel::from_model(&m).unwrap();
        let sdec = Decoder::new(&m, sm).unwrap();
        let ddec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let ids = [2u16, 7, 1, 9, 4, 3];
        let mut c_batch = sdec.new_cache();
        let batched = sdec.prefill_batch(&mut c_batch, &ids).unwrap();
        let mut c_step = sdec.new_cache();
        let stepwise = sdec.prefill(&mut c_step, &ids).unwrap();
        let mut c_dense = ddec.new_cache();
        let dense = ddec.prefill_batch(&mut c_dense, &ids).unwrap();
        for c in 0..m.cfg.vocab {
            assert!((batched[c] - stepwise[c]).abs() < 1e-4, "csr batch vs step c={c}");
            assert!((batched[c] - dense[c]).abs() < 1e-4, "csr vs dense c={c}");
        }
        assert_eq!(c_batch.len(), ids.len());
    }

    #[test]
    fn missing_csr_rejected() {
        let m = random_model(3);
        let sm = SparseModel::from_model(&m).unwrap();
        assert!(sm.mm("nope", &Matrix::zeros(2, 16)).is_err());
    }
}
