//! Rust-native decoder-only transformer forward — numerically mirrors
//! `python/compile/model.py::forward` (same LN eps, tanh-GELU, causal mask,
//! tied unembedding) so the trained weights evaluate identically on both
//! sides. Integration tests pin this against the `model_fwd_*` artifact.

use super::weights::{Tensor, Weights};
use super::ActivationTap;
use crate::config::ModelConfig;
use crate::linalg::matmul::matmul;
use crate::linalg::Matrix;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Captured inputs to the prunable matrices of one block, stacked over the
/// sequences fed to [`Model::forward_collect`].
#[derive(Default)]
pub struct BlockInputs {
    /// Rows of activations per tap (each [n_tokens, dim]).
    pub taps: HashMap<ActivationTap, Matrix>,
}

/// A transformer model: config + weights.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Weights,
}

fn layer_norm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let eps = 1e-5f32;
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / x.cols as f32;
        let var: f32 =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = (row[c] - mean) * inv * g[c] + b[c];
        }
    }
    out
}

/// tanh-approximate GELU (matches jax.nn.gelu default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Result<Self> {
        cfg.validate()?;
        // sanity: required tensors present with the right shapes
        let emb = weights.matrix("tok_emb")?;
        if emb.rows != cfg.vocab || emb.cols != cfg.d_model {
            bail!("tok_emb shape {}x{} != vocab x d_model", emb.rows, emb.cols);
        }
        for i in 0..cfg.n_layers {
            weights.matrix(&format!("blocks.{i}.attn.wq"))?;
            weights.matrix(&format!("blocks.{i}.mlp.w1"))?;
        }
        Ok(Model { cfg, weights })
    }

    /// Load a model from `artifacts/model_{name}.{bin,json}`.
    pub fn load(dir: &std::path::Path, name: &str) -> Result<Self> {
        let cfg = ModelConfig::from_json_file(&dir.join(format!("model_{name}.json")))?;
        let weights = Weights::load(&dir.join(format!("model_{name}.bin")))?;
        Model::new(cfg, weights)
    }

    /// Causal multi-head attention over x [seq, d]. Returns
    /// (output [seq, d], mix [seq, d] — the wo input tap).
    fn attention(&self, x: &Matrix, block: usize) -> Result<(Matrix, Matrix)> {
        let p = format!("blocks.{block}.attn.");
        let wq = self.weights.matrix(&format!("{p}wq"))?;
        let wk = self.weights.matrix(&format!("{p}wk"))?;
        let wv = self.weights.matrix(&format!("{p}wv"))?;
        let wo = self.weights.matrix(&format!("{p}wo"))?;
        let (s, d) = (x.rows, x.cols);
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let q = matmul(x, &wq);
        let k = matmul(x, &wk);
        let v = matmul(x, &wv);

        let mut mix = Matrix::zeros(s, d);
        let scale = 1.0 / (hd as f32).sqrt();
        for head in 0..h {
            let off = head * hd;
            // scores [s, s] for this head
            let mut scores = Matrix::zeros(s, s);
            for i in 0..s {
                let qi = &q.row(i)[off..off + hd];
                for j in 0..=i {
                    let kj = &k.row(j)[off..off + hd];
                    let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                    *scores.at_mut(i, j) = dot * scale;
                }
                for j in (i + 1)..s {
                    *scores.at_mut(i, j) = -1e30; // causal mask
                }
            }
            softmax_rows(&mut scores);
            // mix[:, head] = scores @ v[:, head]
            for i in 0..s {
                let srow = scores.row(i);
                let orow = mix.row_mut(i);
                for j in 0..=i {
                    let sv = srow[j];
                    if sv == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(j)[off..off + hd];
                    for (t, vv) in vrow.iter().enumerate() {
                        orow[off + t] += sv * vv;
                    }
                }
            }
        }
        Ok((matmul(&mix, &wo), mix))
    }

    /// Full forward over one sequence of token ids; returns the final
    /// hidden states [seq, d]. If `collect` is Some((block, sink)),
    /// the prunable-layer inputs of that block are appended to the sink.
    fn forward_hidden(
        &self,
        ids: &[u16],
        mut collect: Option<(usize, &mut BlockInputs)>,
    ) -> Result<Matrix> {
        let s = ids.len();
        if s > self.cfg.seq_len {
            bail!("sequence length {s} exceeds model seq_len {}", self.cfg.seq_len);
        }
        let emb = self.weights.matrix("tok_emb")?;
        let pos = self.weights.matrix("pos_emb")?;
        let d = self.cfg.d_model;
        let mut x = Matrix::zeros(s, d);
        for (t, &id) in ids.iter().enumerate() {
            if (id as usize) >= self.cfg.vocab {
                bail!("token id {id} out of vocab {}", self.cfg.vocab);
            }
            let erow = emb.row(id as usize);
            let prow = pos.row(t);
            let xrow = x.row_mut(t);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
        for b in 0..self.cfg.n_layers {
            let p = format!("blocks.{b}.");
            let h = layer_norm(
                &x,
                self.weights.vector(&format!("{p}ln1.g"))?,
                self.weights.vector(&format!("{p}ln1.b"))?,
            );
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::AttnIn, &h);
                }
            }
            let (attn_out, mix) = self.attention(&h, b)?;
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::AttnOut, &mix);
                }
            }
            x = x.add(&attn_out);
            let h2 = layer_norm(
                &x,
                self.weights.vector(&format!("{p}ln2.g"))?,
                self.weights.vector(&format!("{p}ln2.b"))?,
            );
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::MlpIn, &h2);
                }
            }
            let w1 = self.weights.matrix(&format!("{p}mlp.w1"))?;
            let mut hidden = matmul(&h2, &w1);
            hidden.data.iter_mut().for_each(|v| *v = gelu(*v));
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::MlpHidden, &hidden);
                }
            }
            let w2 = self.weights.matrix(&format!("{p}mlp.w2"))?;
            x = x.add(&matmul(&hidden, &w2));
        }
        Ok(layer_norm(
            &x,
            self.weights.vector("ln_f.g")?,
            self.weights.vector("ln_f.b")?,
        ))
    }

    /// Logits [seq, vocab] (tied unembedding).
    pub fn logits(&self, ids: &[u16]) -> Result<Matrix> {
        let hidden = self.forward_hidden(ids, None)?;
        let emb = self.weights.matrix("tok_emb")?;
        Ok(matmul(&hidden, &emb.transpose()))
    }

    /// Per-position next-token NLL (natural log), length ids.len()-1.
    pub fn nll(&self, ids: &[u16]) -> Result<Vec<f64>> {
        let logits = self.logits(ids)?;
        let mut out = Vec::with_capacity(ids.len() - 1);
        for t in 0..ids.len() - 1 {
            let row = logits.row(t);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse: f64 =
                row.iter().map(|v| ((*v as f64) - max).exp()).sum::<f64>().ln() + max;
            let tgt = row[ids[t + 1] as usize] as f64;
            out.push(lse - tgt);
        }
        Ok(out)
    }

    /// Run sequences collecting the prunable-layer inputs of `block`.
    pub fn forward_collect(&self, seqs: &[Vec<u16>], block: usize) -> Result<BlockInputs> {
        let mut sink = BlockInputs::default();
        for ids in seqs {
            self.forward_hidden(ids, Some((block, &mut sink)))?;
        }
        Ok(sink)
    }

    /// Names of all prunable matrices.
    pub fn prunable_names(&self) -> Vec<String> {
        (0..self.cfg.n_layers)
            .flat_map(|i| super::prunable_layers(i).into_iter().map(|(n, _)| n))
            .collect()
    }

    /// Synthetic Gaussian-initialized model for the given config — used by
    /// benches and the serve demo path when trained artifacts are absent
    /// (unit tests call it with a tiny config via `testutil`).
    pub fn random(cfg: ModelConfig, seed: u64) -> Result<Model> {
        let mut rng = Rng::new(seed);
        let mut w = Weights::default();
        let mut add2 = |w: &mut Weights, name: &str, r: usize, c: usize, rng: &mut Rng| {
            let scale = 1.0 / (r as f32).sqrt();
            let data: Vec<f32> = rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect();
            w.order.push(name.to_string());
            w.tensors.insert(name.to_string(), Tensor { shape: vec![r, c], data });
        };
        let add1 = |w: &mut Weights, name: &str, n: usize, val: f32| {
            w.order.push(name.to_string());
            w.tensors.insert(name.to_string(), Tensor { shape: vec![n], data: vec![val; n] });
        };
        add2(&mut w, "tok_emb", cfg.vocab, cfg.d_model, &mut rng);
        add2(&mut w, "pos_emb", cfg.seq_len, cfg.d_model, &mut rng);
        for i in 0..cfg.n_layers {
            let p = format!("blocks.{i}.");
            add1(&mut w, &format!("{p}ln1.g"), cfg.d_model, 1.0);
            add1(&mut w, &format!("{p}ln1.b"), cfg.d_model, 0.0);
            add2(&mut w, &format!("{p}attn.wq"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wk"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wv"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wo"), cfg.d_model, cfg.d_model, &mut rng);
            add1(&mut w, &format!("{p}ln2.g"), cfg.d_model, 1.0);
            add1(&mut w, &format!("{p}ln2.b"), cfg.d_model, 0.0);
            add2(&mut w, &format!("{p}mlp.w1"), cfg.d_model, cfg.d_ff, &mut rng);
            add2(&mut w, &format!("{p}mlp.w2"), cfg.d_ff, cfg.d_model, &mut rng);
        }
        add1(&mut w, "ln_f.g", cfg.d_model, 1.0);
        add1(&mut w, "ln_f.b", cfg.d_model, 0.0);
        Model::new(cfg, w)
    }
}

// ---------------------------------------------------------------------------
// Incremental (KV-cache) decode — the serving hot path. One decode step
// recomputes only the current token's activations and attends over cached
// K/V rows, so the per-token cost is O(context) attention + O(1) matmuls
// instead of re-running the full prefix through every layer.

/// Per-layer cached attention K/V rows of one sequence.
struct LayerKv {
    k: Matrix,
    v: Matrix,
}

/// Per-sequence decode state: one K and one V row per generated position
/// and layer. Rows are appended by [`Decoder::step_batch`].
pub struct KvCache {
    layers: Vec<LayerKv>,
    len: usize,
}

impl KvCache {
    /// Empty cache for a model with `n_layers` blocks of width `d_model`.
    pub fn new(n_layers: usize, d_model: usize) -> KvCache {
        KvCache {
            layers: (0..n_layers)
                .map(|_| LayerKv { k: Matrix::zeros(0, d_model), v: Matrix::zeros(0, d_model) })
                .collect(),
            len: 0,
        }
    }

    /// Number of positions consumed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Cache memory footprint in bytes (K + V rows across layers).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| (l.k.data.len() + l.v.data.len()) * 4).sum()
    }
}

/// One query row attended over the first `ctx` cached K/V rows of a layer,
/// all heads: scaled dot-product scores, softmax over the live context,
/// weighted-V accumulation into `orow`. Shared by [`Decoder::step_batch`]
/// and [`Decoder::prefill_batch`] so the numerically-sensitive kernel has
/// one definition; `sc` is the caller's score scratch (reused across rows
/// to avoid per-head allocations). Future positions are simply absent from
/// `ctx` — the full forward's -1e30 mask entries underflow to exactly 0.0,
/// so the softmax sums agree.
#[allow(clippy::too_many_arguments)]
fn attend_row(
    q_row: &[f32],
    lk: &LayerKv,
    ctx: usize,
    heads: usize,
    hd: usize,
    scale: f32,
    sc: &mut Vec<f32>,
    orow: &mut [f32],
) {
    for head in 0..heads {
        let off = head * hd;
        let qi = &q_row[off..off + hd];
        sc.clear();
        sc.resize(ctx, 0.0);
        for (j, s) in sc.iter_mut().enumerate() {
            let kj = &lk.k.row(j)[off..off + hd];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            *s = dot * scale;
        }
        let max = sc.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for s in sc.iter_mut() {
            *s = (*s - max).exp();
            sum += *s;
        }
        for s in sc.iter_mut() {
            *s /= sum;
        }
        for (j, &sv) in sc.iter().enumerate() {
            if sv == 0.0 {
                continue;
            }
            let vrow = &lk.v.row(j)[off..off + hd];
            for (t, vv) in vrow.iter().enumerate() {
                orow[off + t] += sv * vv;
            }
        }
    }
}

/// How a named prunable weight matrix is applied to activation rows —
/// dense matmul ([`DenseOps`]) or CSR kernels (`SparseModel`). This is the
/// seam that lets one decode implementation serve both weight formats.
pub trait DecodeOps {
    /// y = x @ W\[name\] for activation rows x (\[batch, n_in\]).
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix>;
}

impl<O: DecodeOps + ?Sized> DecodeOps for Box<O> {
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        (**self).apply(name, x)
    }
}

/// Dense decode backend: prunable matrices resolved once up front so the
/// per-step path never clones weight tensors.
pub struct DenseOps {
    mats: HashMap<String, Matrix>,
}

impl DenseOps {
    pub fn new(model: &Model) -> Result<DenseOps> {
        let mut mats = HashMap::new();
        for name in model.prunable_names() {
            let w = model.weights.matrix(&name)?;
            mats.insert(name, w);
        }
        Ok(DenseOps { mats })
    }
}

impl DecodeOps for DenseOps {
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        match self.mats.get(name) {
            Some(w) => Ok(matmul(x, w)),
            None => bail!("no dense weight '{name}'"),
        }
    }
}

/// Pre-built weight/param names of one block — the decode hot path calls
/// into name-keyed maps every layer of every step, so the `format!`
/// allocations are hoisted to construction time.
struct BlockNames {
    ln1_g: String,
    ln1_b: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

impl BlockNames {
    fn new(block: usize) -> BlockNames {
        let p = format!("blocks.{block}.");
        BlockNames {
            ln1_g: format!("{p}ln1.g"),
            ln1_b: format!("{p}ln1.b"),
            wq: format!("{p}attn.wq"),
            wk: format!("{p}attn.wk"),
            wv: format!("{p}attn.wv"),
            wo: format!("{p}attn.wo"),
            ln2_g: format!("{p}ln2.g"),
            ln2_b: format!("{p}ln2.b"),
            w1: format!("{p}mlp.w1"),
            w2: format!("{p}mlp.w2"),
        }
    }
}

/// Incremental decoder: model + weight backend + pre-transposed
/// unembedding. Numerically pins to [`Model::logits`] (tests assert the
/// per-position logits match the full-prefix forward).
pub struct Decoder<'m, O: DecodeOps> {
    model: &'m Model,
    ops: O,
    emb_t: Matrix,
    names: Vec<BlockNames>,
}

impl<'m, O: DecodeOps> Decoder<'m, O> {
    pub fn new(model: &'m Model, ops: O) -> Result<Decoder<'m, O>> {
        let emb_t = model.weights.matrix("tok_emb")?.transpose();
        let names = (0..model.cfg.n_layers).map(BlockNames::new).collect();
        Ok(Decoder { model, ops, emb_t, names })
    }

    pub fn model(&self) -> &'m Model {
        self.model
    }

    /// Fresh per-sequence cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.model.cfg.n_layers, self.model.cfg.d_model)
    }

    /// Feed one token for one sequence; returns the next-token logits row.
    pub fn step(&self, cache: &mut KvCache, token: u16) -> Result<Vec<f32>> {
        let logits = self.step_batch(&mut [cache], &[token])?;
        Ok(logits.row(0).to_vec())
    }

    /// Feed the whole prompt token by token; returns the logits after the
    /// final prompt token (the distribution of the first generated token).
    ///
    /// Reference path: O(prompt) single-row passes. Serving admission uses
    /// [`Decoder::prefill_batch`] instead (one multi-row pass per layer);
    /// this stays as the exactness baseline for tests and benches.
    pub fn prefill(&self, cache: &mut KvCache, prompt: &[u16]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let mut last = Vec::new();
        for &t in prompt {
            last = self.step(cache, t)?;
        }
        Ok(last)
    }

    /// Consume the whole prompt as one `[prompt, d_model]` pass per layer —
    /// the SparseGPT-style layer-batched formulation. Every linear layer
    /// runs once over all prompt rows (fanning across the matmul thread
    /// pool via the [`DecodeOps`] seam), so admission costs O(layers)
    /// batched matmuls instead of O(prompt) single-row passes. Attention is
    /// causally masked over the growing KV cache: row `i` (global position
    /// `t0 + i`, where `t0` is the pre-existing cache length) attends to
    /// cached positions `0..=t0+i`, so a partially-filled cache can be
    /// extended mid-sequence. Returns the logits after the final prompt
    /// token, numerically matching [`Decoder::prefill`].
    ///
    /// Token/capacity validation happens before any cache mutation; a later
    /// structural error (missing weight) leaves the cache partially
    /// advanced, same caveat as [`Decoder::step_batch`].
    pub fn prefill_batch(&self, cache: &mut KvCache, prompt: &[u16]) -> Result<Vec<f32>> {
        let m = self.model;
        let cfg = &m.cfg;
        let s = prompt.len();
        let t0 = cache.len;
        self.validate_prompt(t0, prompt)?;
        let d = cfg.d_model;
        let emb = m.weights.get("tok_emb")?;
        let pos = m.weights.get("pos_emb")?;
        let mut x = Matrix::zeros(s, d);
        for (i, &tok) in prompt.iter().enumerate() {
            let erow = &emb.data[(tok as usize) * d..(tok as usize + 1) * d];
            let prow = &pos.data[(t0 + i) * d..(t0 + i + 1) * d];
            let xrow = x.row_mut(i);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
        let hd = cfg.head_dim();
        let heads = cfg.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut sc: Vec<f32> = Vec::with_capacity(t0 + s);
        for b in 0..cfg.n_layers {
            let names = &self.names[b];
            let h = layer_norm(
                &x,
                m.weights.vector(&names.ln1_g)?,
                m.weights.vector(&names.ln1_b)?,
            );
            // the batched win: each projection is one [s, d] product
            let q = self.ops.apply(&names.wq, &h)?;
            let k = self.ops.apply(&names.wk, &h)?;
            let v = self.ops.apply(&names.wv, &h)?;
            let lk = &mut cache.layers[b];
            lk.k.data.extend_from_slice(&k.data);
            lk.k.rows += s;
            lk.v.data.extend_from_slice(&v.data);
            lk.v.rows += s;
            let mut mix = Matrix::zeros(s, d);
            for i in 0..s {
                // causal mask: position t0+i sees the cached prefix plus
                // itself; the rows we just appended past it are excluded
                let ctx = t0 + i + 1;
                attend_row(q.row(i), lk, ctx, heads, hd, scale, &mut sc, mix.row_mut(i));
            }
            let attn_out = self.ops.apply(&names.wo, &mix)?;
            x = x.add(&attn_out);
            let h2 = layer_norm(
                &x,
                m.weights.vector(&names.ln2_g)?,
                m.weights.vector(&names.ln2_b)?,
            );
            let mut hidden = self.ops.apply(&names.w1, &h2)?;
            hidden.data.iter_mut().for_each(|vv| *vv = gelu(*vv));
            x = x.add(&self.ops.apply(&names.w2, &hidden)?);
        }
        cache.len += s;
        // only the last position's logits are needed; layer norm is
        // per-row, so norming just row s-1 before the unembed is exact
        let last = Matrix::from_vec(1, d, x.row(s - 1).to_vec());
        let hf = layer_norm(&last, m.weights.vector("ln_f.g")?, m.weights.vector("ln_f.b")?);
        Ok(matmul(&hf, &self.emb_t).row(0).to_vec())
    }

    /// Validate a prompt against this model and a cache position without
    /// touching any state: non-empty, within context capacity, every id
    /// in vocab. Shared by [`Decoder::prefill_batch`] and the batcher's
    /// zero-decode admission path so both report identical errors.
    pub fn validate_prompt(&self, cached: usize, prompt: &[u16]) -> Result<()> {
        let cfg = &self.model.cfg;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if cached + prompt.len() > cfg.seq_len {
            bail!(
                "prompt length {} + cached {cached} exceeds model seq_len {}",
                prompt.len(),
                cfg.seq_len
            );
        }
        if let Some(&t) = prompt.iter().find(|&&t| (t as usize) >= cfg.vocab) {
            bail!("token id {t} out of vocab {}", cfg.vocab);
        }
        Ok(())
    }

    /// One decode step over a batch of independent sequences (each with its
    /// own cache and position). The linear layers run as one [batch, d]
    /// matrix product — fanning the batch across the matmul thread pool —
    /// while attention loops per sequence over its cached K/V rows.
    /// Returns next-token logits [batch, vocab].
    ///
    /// Validation (vocab bounds, cache capacity) happens before any cache
    /// mutation; a later structural error (missing weight) leaves caches
    /// partially advanced.
    pub fn step_batch(&self, caches: &mut [&mut KvCache], tokens: &[u16]) -> Result<Matrix> {
        let m = self.model;
        let cfg = &m.cfg;
        let bsz = tokens.len();
        if bsz == 0 || caches.len() != bsz {
            bail!("decode batch mismatch: {} caches, {} tokens", caches.len(), bsz);
        }
        let d = cfg.d_model;
        let emb = m.weights.get("tok_emb")?;
        let pos = m.weights.get("pos_emb")?;
        let mut x = Matrix::zeros(bsz, d);
        for (i, &tok) in tokens.iter().enumerate() {
            if (tok as usize) >= cfg.vocab {
                bail!("token id {tok} out of vocab {}", cfg.vocab);
            }
            let t = caches[i].len;
            if t >= cfg.seq_len {
                bail!("KV cache full: position {t} >= seq_len {}", cfg.seq_len);
            }
            let erow = &emb.data[(tok as usize) * d..(tok as usize + 1) * d];
            let prow = &pos.data[t * d..(t + 1) * d];
            let xrow = x.row_mut(i);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
        let hd = cfg.head_dim();
        let heads = cfg.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        // attention-score scratch, reused across layers/sequences/heads so
        // the hot path allocates once per step instead of per head
        let mut sc: Vec<f32> = Vec::with_capacity(cfg.seq_len);
        for b in 0..cfg.n_layers {
            let names = &self.names[b];
            let h = layer_norm(
                &x,
                m.weights.vector(&names.ln1_g)?,
                m.weights.vector(&names.ln1_b)?,
            );
            let q = self.ops.apply(&names.wq, &h)?;
            let k = self.ops.apply(&names.wk, &h)?;
            let v = self.ops.apply(&names.wv, &h)?;
            let mut mix = Matrix::zeros(bsz, d);
            for i in 0..bsz {
                let lk = &mut caches[i].layers[b];
                lk.k.data.extend_from_slice(k.row(i));
                lk.k.rows += 1;
                lk.v.data.extend_from_slice(v.row(i));
                lk.v.rows += 1;
                let ctx = lk.k.rows;
                attend_row(q.row(i), lk, ctx, heads, hd, scale, &mut sc, mix.row_mut(i));
            }
            let attn_out = self.ops.apply(&names.wo, &mix)?;
            x = x.add(&attn_out);
            let h2 = layer_norm(
                &x,
                m.weights.vector(&names.ln2_g)?,
                m.weights.vector(&names.ln2_b)?,
            );
            let mut hidden = self.ops.apply(&names.w1, &h2)?;
            hidden.data.iter_mut().for_each(|vv| *vv = gelu(*vv));
            x = x.add(&self.ops.apply(&names.w2, &hidden)?);
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        let hf = layer_norm(&x, m.weights.vector("ln_f.g")?, m.weights.vector("ln_f.b")?);
        Ok(matmul(&hf, &self.emb_t))
    }
}

fn append_rows(sink: &mut BlockInputs, tap: ActivationTap, m: &Matrix) {
    let entry = sink
        .taps
        .entry(tap)
        .or_insert_with(|| Matrix::zeros(0, m.cols));
    debug_assert_eq!(entry.cols, m.cols);
    entry.data.extend_from_slice(&m.data);
    entry.rows += m.rows;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Tiny random model for unit tests.
    pub fn random_model(seed: u64) -> Model {
        let cfg = ModelConfig {
            name: "test".into(),
            d_model: 16,
            d_ff: 32,
            n_layers: 2,
            n_heads: 4,
            vocab: 24,
            seq_len: 12,
        };
        Model::random(cfg, seed).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::random_model;
    use super::*;

    #[test]
    fn logits_shape() {
        let m = random_model(0);
        let logits = m.logits(&[1, 2, 3, 4]).unwrap();
        assert_eq!((logits.rows, logits.cols), (4, 24));
    }

    #[test]
    fn nll_positive_and_near_uniform_for_random_weights() {
        let m = random_model(1);
        let nll = m.nll(&[0, 5, 9, 3, 7, 2]).unwrap();
        assert_eq!(nll.len(), 5);
        let mean: f64 = nll.iter().sum::<f64>() / nll.len() as f64;
        assert!(mean > 0.0);
        assert!((mean - (24f64).ln()).abs() < 1.5, "mean nll {mean}");
    }

    #[test]
    fn causality() {
        // changing a later token must not affect earlier logits
        let m = random_model(2);
        let a = m.logits(&[1, 2, 3, 4, 5]).unwrap();
        let b = m.logits(&[1, 2, 3, 9, 9]).unwrap();
        for t in 0..3 {
            for c in 0..24 {
                assert!((a.at(t, c) - b.at(t, c)).abs() < 1e-4, "t={t}");
            }
        }
    }

    #[test]
    fn collect_taps_shapes() {
        let m = random_model(3);
        let seqs = vec![vec![1u16, 2, 3, 4], vec![5, 6, 7, 8]];
        let s = m.forward_collect(&seqs, 1).unwrap();
        let attn = &s.taps[&ActivationTap::AttnIn];
        assert_eq!((attn.rows, attn.cols), (8, 16));
        let hid = &s.taps[&ActivationTap::MlpHidden];
        assert_eq!((hid.rows, hid.cols), (8, 32));
        assert_eq!(s.taps.len(), 4);
    }

    #[test]
    fn rejects_oversized_sequence() {
        let m = random_model(4);
        let ids: Vec<u16> = (0..13).map(|i| i as u16).collect();
        assert!(m.logits(&ids).is_err());
    }

    #[test]
    fn rejects_out_of_vocab() {
        let m = random_model(5);
        assert!(m.logits(&[0, 200]).is_err());
    }

    #[test]
    fn zeroing_weights_changes_output() {
        let mut m = random_model(6);
        let before = m.nll(&[1, 2, 3, 4, 5, 6]).unwrap();
        let name = "blocks.0.mlp.w1";
        let z = Matrix::zeros(16, 32);
        m.weights.set_matrix(name, &z).unwrap();
        let after = m.nll(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert!(before.iter().zip(&after).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn prunable_names_count() {
        let m = random_model(7);
        assert_eq!(m.prunable_names().len(), 2 * 6);
    }

    #[test]
    fn kv_decode_matches_full_forward() {
        // the tentpole exactness pin: incremental decode with a KV cache
        // reproduces the full-prefix forward at every position
        let m = random_model(8);
        let ids = [1u16, 5, 9, 3, 7, 2, 11];
        let full = m.logits(&ids).unwrap();
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let mut cache = dec.new_cache();
        for (t, &tok) in ids.iter().enumerate() {
            let logits = dec.step(&mut cache, tok).unwrap();
            for c in 0..m.cfg.vocab {
                assert!(
                    (logits[c] - full.at(t, c)).abs() < 1e-4,
                    "t={t} c={c}: {} vs {}",
                    logits[c],
                    full.at(t, c)
                );
            }
        }
        assert_eq!(cache.len(), ids.len());
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn batched_decode_matches_single_at_mixed_positions() {
        // sequences admitted at different times (continuous batching) —
        // each row carries its own position
        let m = random_model(9);
        let a = [1u16, 2, 3, 4];
        let b = [5u16, 6, 7];
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let mut ca = dec.new_cache();
        dec.step(&mut ca, a[0]).unwrap(); // a is one step ahead of b
        let mut cb = dec.new_cache();
        let mut last = Matrix::zeros(0, 0);
        for i in 0..3 {
            last = dec.step_batch(&mut [&mut ca, &mut cb], &[a[i + 1], b[i]]).unwrap();
        }
        let fa = m.logits(&a).unwrap();
        let fb = m.logits(&b).unwrap();
        for c in 0..m.cfg.vocab {
            assert!((last.at(0, c) - fa.at(3, c)).abs() < 1e-4);
            assert!((last.at(1, c) - fb.at(2, c)).abs() < 1e-4);
        }
    }

    #[test]
    fn decode_rejects_overflow_and_bad_tokens() {
        let m = random_model(10);
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let mut cache = dec.new_cache();
        assert!(dec.step(&mut cache, 200).is_err()); // out of vocab
        assert_eq!(cache.len(), 0); // rejected before mutation
        for t in 0..m.cfg.seq_len {
            dec.step(&mut cache, (t % 24) as u16).unwrap();
        }
        assert!(dec.step(&mut cache, 0).is_err()); // context full
        assert!(dec.prefill(&mut dec.new_cache(), &[]).is_err());
    }

    #[test]
    fn prefill_matches_stepwise() {
        let m = random_model(11);
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let ids = [3u16, 1, 4, 1, 5];
        let mut cache = dec.new_cache();
        let logits = dec.prefill(&mut cache, &ids).unwrap();
        let full = m.logits(&ids).unwrap();
        for c in 0..m.cfg.vocab {
            assert!((logits[c] - full.at(4, c)).abs() < 1e-4);
        }
    }

    #[test]
    fn prefill_batch_matches_stepwise_prefill() {
        // the admission tentpole: one [prompt, d] pass per layer must be
        // numerically interchangeable with O(prompt) single-row passes
        let m = random_model(12);
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let ids = [3u16, 1, 4, 1, 5, 9, 2];
        let mut c_step = dec.new_cache();
        let a = dec.prefill(&mut c_step, &ids).unwrap();
        let mut c_batch = dec.new_cache();
        let b = dec.prefill_batch(&mut c_batch, &ids).unwrap();
        assert_eq!(c_batch.len(), ids.len());
        for c in 0..m.cfg.vocab {
            assert!((a[c] - b[c]).abs() < 1e-4, "c={c}: {} vs {}", a[c], b[c]);
        }
        // the caches must be interchangeable too: continuing decode from
        // the batched cache matches continuing from the stepwise cache
        let sa = dec.step(&mut c_step, 7).unwrap();
        let sb = dec.step(&mut c_batch, 7).unwrap();
        for c in 0..m.cfg.vocab {
            assert!((sa[c] - sb[c]).abs() < 1e-4, "post-step c={c}");
        }
    }

    #[test]
    fn prefill_batch_extends_partial_cache() {
        // prefix fed stepwise, suffix fed batched: the causal mask must
        // offset by the pre-existing cache length
        let m = random_model(13);
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let ids = [2u16, 7, 1, 9, 4, 3];
        let mut cache = dec.new_cache();
        dec.step(&mut cache, ids[0]).unwrap();
        dec.step(&mut cache, ids[1]).unwrap();
        let logits = dec.prefill_batch(&mut cache, &ids[2..]).unwrap();
        assert_eq!(cache.len(), ids.len());
        let full = m.logits(&ids).unwrap();
        for c in 0..m.cfg.vocab {
            assert!(
                (logits[c] - full.at(ids.len() - 1, c)).abs() < 1e-4,
                "c={c}: {} vs {}",
                logits[c],
                full.at(ids.len() - 1, c)
            );
        }
    }

    #[test]
    fn prefill_batch_rejects_before_mutation() {
        let m = random_model(14);
        let dec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let mut cache = dec.new_cache();
        assert!(dec.prefill_batch(&mut cache, &[]).is_err());
        assert!(dec.prefill_batch(&mut cache, &[1, 200, 2]).is_err()); // out of vocab
        assert_eq!(cache.len(), 0, "rejected prompt must not advance the cache");
        let too_long: Vec<u16> = (0..13).map(|i| (i % 24) as u16).collect();
        assert!(dec.prefill_batch(&mut cache, &too_long).is_err()); // > seq_len
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn gelu_matches_reference_points() {
        // values from jax.nn.gelu (tanh approximation)
        assert!((gelu(0.0) - 0.0).abs() < 1e-6);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) - (-0.158_808)).abs() < 1e-4);
        assert!((gelu(3.0) - 2.995_9).abs() < 1e-3);
    }
}
