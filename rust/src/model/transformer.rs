//! Rust-native decoder-only transformer forward — numerically mirrors
//! `python/compile/model.py::forward` (same LN eps, tanh-GELU, causal mask,
//! tied unembedding) so the trained weights evaluate identically on both
//! sides. Integration tests pin this against the `model_fwd_*` artifact.

use super::weights::Weights;
use super::ActivationTap;
use crate::config::ModelConfig;
use crate::linalg::matmul::matmul;
use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Captured inputs to the prunable matrices of one block, stacked over the
/// sequences fed to [`Model::forward_collect`].
#[derive(Default)]
pub struct BlockInputs {
    /// Rows of activations per tap (each [n_tokens, dim]).
    pub taps: HashMap<ActivationTap, Matrix>,
}

/// A transformer model: config + weights.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Weights,
}

fn layer_norm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let eps = 1e-5f32;
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / x.cols as f32;
        let var: f32 =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = (row[c] - mean) * inv * g[c] + b[c];
        }
    }
    out
}

/// tanh-approximate GELU (matches jax.nn.gelu default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Result<Self> {
        cfg.validate()?;
        // sanity: required tensors present with the right shapes
        let emb = weights.matrix("tok_emb")?;
        if emb.rows != cfg.vocab || emb.cols != cfg.d_model {
            bail!("tok_emb shape {}x{} != vocab x d_model", emb.rows, emb.cols);
        }
        for i in 0..cfg.n_layers {
            weights.matrix(&format!("blocks.{i}.attn.wq"))?;
            weights.matrix(&format!("blocks.{i}.mlp.w1"))?;
        }
        Ok(Model { cfg, weights })
    }

    /// Load a model from `artifacts/model_{name}.{bin,json}`.
    pub fn load(dir: &std::path::Path, name: &str) -> Result<Self> {
        let cfg = ModelConfig::from_json_file(&dir.join(format!("model_{name}.json")))?;
        let weights = Weights::load(&dir.join(format!("model_{name}.bin")))?;
        Model::new(cfg, weights)
    }

    /// Causal multi-head attention over x [seq, d]. Returns
    /// (output [seq, d], mix [seq, d] — the wo input tap).
    fn attention(&self, x: &Matrix, block: usize) -> Result<(Matrix, Matrix)> {
        let p = format!("blocks.{block}.attn.");
        let wq = self.weights.matrix(&format!("{p}wq"))?;
        let wk = self.weights.matrix(&format!("{p}wk"))?;
        let wv = self.weights.matrix(&format!("{p}wv"))?;
        let wo = self.weights.matrix(&format!("{p}wo"))?;
        let (s, d) = (x.rows, x.cols);
        let h = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let q = matmul(x, &wq);
        let k = matmul(x, &wk);
        let v = matmul(x, &wv);

        let mut mix = Matrix::zeros(s, d);
        let scale = 1.0 / (hd as f32).sqrt();
        for head in 0..h {
            let off = head * hd;
            // scores [s, s] for this head
            let mut scores = Matrix::zeros(s, s);
            for i in 0..s {
                let qi = &q.row(i)[off..off + hd];
                for j in 0..=i {
                    let kj = &k.row(j)[off..off + hd];
                    let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                    *scores.at_mut(i, j) = dot * scale;
                }
                for j in (i + 1)..s {
                    *scores.at_mut(i, j) = -1e30; // causal mask
                }
            }
            softmax_rows(&mut scores);
            // mix[:, head] = scores @ v[:, head]
            for i in 0..s {
                let srow = scores.row(i);
                let orow = mix.row_mut(i);
                for j in 0..=i {
                    let sv = srow[j];
                    if sv == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(j)[off..off + hd];
                    for (t, vv) in vrow.iter().enumerate() {
                        orow[off + t] += sv * vv;
                    }
                }
            }
        }
        Ok((matmul(&mix, &wo), mix))
    }

    /// Full forward over one sequence of token ids; returns the final
    /// hidden states [seq, d]. If `collect` is Some((block, sink)),
    /// the prunable-layer inputs of that block are appended to the sink.
    fn forward_hidden(
        &self,
        ids: &[u16],
        mut collect: Option<(usize, &mut BlockInputs)>,
    ) -> Result<Matrix> {
        let s = ids.len();
        if s > self.cfg.seq_len {
            bail!("sequence length {s} exceeds model seq_len {}", self.cfg.seq_len);
        }
        let emb = self.weights.matrix("tok_emb")?;
        let pos = self.weights.matrix("pos_emb")?;
        let d = self.cfg.d_model;
        let mut x = Matrix::zeros(s, d);
        for (t, &id) in ids.iter().enumerate() {
            if (id as usize) >= self.cfg.vocab {
                bail!("token id {id} out of vocab {}", self.cfg.vocab);
            }
            let erow = emb.row(id as usize);
            let prow = pos.row(t);
            let xrow = x.row_mut(t);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
        for b in 0..self.cfg.n_layers {
            let p = format!("blocks.{b}.");
            let h = layer_norm(
                &x,
                self.weights.vector(&format!("{p}ln1.g"))?,
                self.weights.vector(&format!("{p}ln1.b"))?,
            );
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::AttnIn, &h);
                }
            }
            let (attn_out, mix) = self.attention(&h, b)?;
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::AttnOut, &mix);
                }
            }
            x = x.add(&attn_out);
            let h2 = layer_norm(
                &x,
                self.weights.vector(&format!("{p}ln2.g"))?,
                self.weights.vector(&format!("{p}ln2.b"))?,
            );
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::MlpIn, &h2);
                }
            }
            let w1 = self.weights.matrix(&format!("{p}mlp.w1"))?;
            let mut hidden = matmul(&h2, &w1);
            hidden.data.iter_mut().for_each(|v| *v = gelu(*v));
            if let Some((cb, sink)) = collect.as_mut() {
                if *cb == b {
                    append_rows(sink, ActivationTap::MlpHidden, &hidden);
                }
            }
            let w2 = self.weights.matrix(&format!("{p}mlp.w2"))?;
            x = x.add(&matmul(&hidden, &w2));
        }
        Ok(layer_norm(
            &x,
            self.weights.vector("ln_f.g")?,
            self.weights.vector("ln_f.b")?,
        ))
    }

    /// Logits [seq, vocab] (tied unembedding).
    pub fn logits(&self, ids: &[u16]) -> Result<Matrix> {
        let hidden = self.forward_hidden(ids, None)?;
        let emb = self.weights.matrix("tok_emb")?;
        Ok(matmul(&hidden, &emb.transpose()))
    }

    /// Per-position next-token NLL (natural log), length ids.len()-1.
    pub fn nll(&self, ids: &[u16]) -> Result<Vec<f64>> {
        let logits = self.logits(ids)?;
        let mut out = Vec::with_capacity(ids.len() - 1);
        for t in 0..ids.len() - 1 {
            let row = logits.row(t);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse: f64 =
                row.iter().map(|v| ((*v as f64) - max).exp()).sum::<f64>().ln() + max;
            let tgt = row[ids[t + 1] as usize] as f64;
            out.push(lse - tgt);
        }
        Ok(out)
    }

    /// Run sequences collecting the prunable-layer inputs of `block`.
    pub fn forward_collect(&self, seqs: &[Vec<u16>], block: usize) -> Result<BlockInputs> {
        let mut sink = BlockInputs::default();
        for ids in seqs {
            self.forward_hidden(ids, Some((block, &mut sink)))?;
        }
        Ok(sink)
    }

    /// Names of all prunable matrices.
    pub fn prunable_names(&self) -> Vec<String> {
        (0..self.cfg.n_layers)
            .flat_map(|i| super::prunable_layers(i).into_iter().map(|(n, _)| n))
            .collect()
    }
}

fn append_rows(sink: &mut BlockInputs, tap: ActivationTap, m: &Matrix) {
    let entry = sink
        .taps
        .entry(tap)
        .or_insert_with(|| Matrix::zeros(0, m.cols));
    debug_assert_eq!(entry.cols, m.cols);
    entry.data.extend_from_slice(&m.data);
    entry.rows += m.rows;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::model::weights::Tensor;
    use crate::util::Rng;

    /// Tiny random model for unit tests.
    pub fn random_model(seed: u64) -> Model {
        let cfg = ModelConfig {
            name: "test".into(),
            d_model: 16,
            d_ff: 32,
            n_layers: 2,
            n_heads: 4,
            vocab: 24,
            seq_len: 12,
        };
        let mut rng = Rng::new(seed);
        let mut w = Weights::default();
        let mut add2 = |w: &mut Weights, name: &str, r: usize, c: usize, rng: &mut Rng| {
            let scale = 1.0 / (r as f32).sqrt();
            let data: Vec<f32> = rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect();
            w.order.push(name.to_string());
            w.tensors.insert(name.to_string(), Tensor { shape: vec![r, c], data });
        };
        let add1 = |w: &mut Weights, name: &str, n: usize, val: f32| {
            w.order.push(name.to_string());
            w.tensors.insert(name.to_string(), Tensor { shape: vec![n], data: vec![val; n] });
        };
        add2(&mut w, "tok_emb", cfg.vocab, cfg.d_model, &mut rng);
        add2(&mut w, "pos_emb", cfg.seq_len, cfg.d_model, &mut rng);
        for i in 0..cfg.n_layers {
            let p = format!("blocks.{i}.");
            add1(&mut w, &format!("{p}ln1.g"), cfg.d_model, 1.0);
            add1(&mut w, &format!("{p}ln1.b"), cfg.d_model, 0.0);
            add2(&mut w, &format!("{p}attn.wq"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wk"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wv"), cfg.d_model, cfg.d_model, &mut rng);
            add2(&mut w, &format!("{p}attn.wo"), cfg.d_model, cfg.d_model, &mut rng);
            add1(&mut w, &format!("{p}ln2.g"), cfg.d_model, 1.0);
            add1(&mut w, &format!("{p}ln2.b"), cfg.d_model, 0.0);
            add2(&mut w, &format!("{p}mlp.w1"), cfg.d_model, cfg.d_ff, &mut rng);
            add2(&mut w, &format!("{p}mlp.w2"), cfg.d_ff, cfg.d_model, &mut rng);
        }
        add1(&mut w, "ln_f.g", cfg.d_model, 1.0);
        add1(&mut w, "ln_f.b", cfg.d_model, 0.0);
        Model::new(cfg, w).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::random_model;
    use super::*;

    #[test]
    fn logits_shape() {
        let m = random_model(0);
        let logits = m.logits(&[1, 2, 3, 4]).unwrap();
        assert_eq!((logits.rows, logits.cols), (4, 24));
    }

    #[test]
    fn nll_positive_and_near_uniform_for_random_weights() {
        let m = random_model(1);
        let nll = m.nll(&[0, 5, 9, 3, 7, 2]).unwrap();
        assert_eq!(nll.len(), 5);
        let mean: f64 = nll.iter().sum::<f64>() / nll.len() as f64;
        assert!(mean > 0.0);
        assert!((mean - (24f64).ln()).abs() < 1.5, "mean nll {mean}");
    }

    #[test]
    fn causality() {
        // changing a later token must not affect earlier logits
        let m = random_model(2);
        let a = m.logits(&[1, 2, 3, 4, 5]).unwrap();
        let b = m.logits(&[1, 2, 3, 9, 9]).unwrap();
        for t in 0..3 {
            for c in 0..24 {
                assert!((a.at(t, c) - b.at(t, c)).abs() < 1e-4, "t={t}");
            }
        }
    }

    #[test]
    fn collect_taps_shapes() {
        let m = random_model(3);
        let seqs = vec![vec![1u16, 2, 3, 4], vec![5, 6, 7, 8]];
        let s = m.forward_collect(&seqs, 1).unwrap();
        let attn = &s.taps[&ActivationTap::AttnIn];
        assert_eq!((attn.rows, attn.cols), (8, 16));
        let hid = &s.taps[&ActivationTap::MlpHidden];
        assert_eq!((hid.rows, hid.cols), (8, 32));
        assert_eq!(s.taps.len(), 4);
    }

    #[test]
    fn rejects_oversized_sequence() {
        let m = random_model(4);
        let ids: Vec<u16> = (0..13).map(|i| i as u16).collect();
        assert!(m.logits(&ids).is_err());
    }

    #[test]
    fn rejects_out_of_vocab() {
        let m = random_model(5);
        assert!(m.logits(&[0, 200]).is_err());
    }

    #[test]
    fn zeroing_weights_changes_output() {
        let mut m = random_model(6);
        let before = m.nll(&[1, 2, 3, 4, 5, 6]).unwrap();
        let name = "blocks.0.mlp.w1";
        let z = Matrix::zeros(16, 32);
        m.weights.set_matrix(name, &z).unwrap();
        let after = m.nll(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert!(before.iter().zip(&after).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn prunable_names_count() {
        let m = random_model(7);
        assert_eq!(m.prunable_names().len(), 2 * 6);
    }

    #[test]
    fn gelu_matches_reference_points() {
        // values from jax.nn.gelu (tanh approximation)
        assert!((gelu(0.0) - 0.0).abs() < 1e-6);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) - (-0.158_808)).abs() < 1e-4);
        assert!((gelu(3.0) - 2.995_9).abs() < 1e-3);
    }
}
