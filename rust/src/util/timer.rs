//! Wall-clock timing helpers for the bench harness and coordinator metrics.

use std::time::Instant;

/// Simple start/elapsed timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn restart_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let first = t.restart();
        assert!(first > 0.0);
        assert!(t.elapsed_secs() < first + 1.0);
    }
}
