//! Summary statistics used by the bench harness and result tables.

/// Accumulated sample statistics (mean/std/min/max/percentiles).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats { samples: Vec::new() }
    }

    pub fn from_samples(samples: &[f64]) -> Self {
        Stats { samples: samples.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted samples (q in [0,100]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        // total order: NaN samples sort after every finite value instead of
        // panicking the comparator (latency windows are fed external data)
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = (q / 100.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let frac = pos - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = Stats::from_samples(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert!((s.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Stats::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn single_sample() {
        let s = Stats::from_samples(&[7.0]);
        assert_eq!(s.mean(), 7.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.median(), 7.0);
    }

    #[test]
    fn min_max() {
        let s = Stats::from_samples(&[3.0, -1.0, 5.0]);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 5.0);
    }
}
