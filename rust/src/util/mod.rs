//! Small shared utilities: deterministic PRNG, statistics, timers, tables.
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use rng::Rng;
pub use stats::Stats;
pub use timer::Timer;
