//! Plain-text table rendering for bench outputs (criterion is unavailable
//! offline, so the bench harness prints paper-style tables itself).

/// Column-aligned text table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float the way the paper's tables do (3-4 significant digits,
/// scientific for very small values).
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a < 1e-2 || a >= 1e5 {
        format!("{:.2e}", x)
    } else if a < 1.0 {
        format!("{:.4}", x)
    } else if a < 100.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.1}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "err"]);
        t.row_strs(&["MP", "0.12"]);
        t.row_strs(&["ALPS", "0.05"]);
        let s = t.render();
        assert!(s.contains("| method |"));
        assert!(s.lines().count() == 4);
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0), "0");
        assert_eq!(fmt_sig(0.00756), "7.56e-3");
        assert_eq!(fmt_sig(0.1234), "0.1234");
        assert_eq!(fmt_sig(12.345), "12.35");
        assert_eq!(fmt_sig(524559.0), "5.25e5");
    }
}
