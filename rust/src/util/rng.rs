//! Deterministic PRNG (splitmix64 + xoshiro-style helpers).
//!
//! Shares constants and test vectors with `python/compile/corpus.py` so the
//! two sides can generate identical streams when needed.

/// SplitMix64 generator: tiny, fast, full 64-bit state jump.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `count` indices without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, count: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(count.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // same vector pinned in python/tests/test_corpus.py
        let mut r = Rng::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(42);
        let v = r.gaussian_vec(20_000);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / v.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..100 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_unique() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 10);
    }

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = {
            let mut r = Rng::new(123);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(123);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
