//! The packed N:M weight representation: exactly `n` stored slots per
//! group of `m` consecutive input rows of each output column, matching
//! the grouping of [`crate::pruning::projection::nm_project`].
//!
//! Layout (the whole point — no indptr, perfectly strided access):
//!
//! * `values` — column-major slot stream, `cols * groups * n` f32s at
//!   slot `s = (c * groups + g) * n + j`, so the decode gather for one
//!   output column reads its values sequentially.
//! * `idx` — in-group row offsets, bit-packed into `u64` words at
//!   `bits = ceil(log2(m))` rounded up to a power of two (2 bits for
//!   2:4), so a packed index never straddles a word boundary: slot `s`
//!   lives at bit offset `s * bits`.
//!
//! A group holding fewer than `n` nonzeros is padded with `0.0` values
//! at the smallest unused in-group offsets; within every group the `n`
//! stored offsets are strictly ascending ([`NmPacked::from_parts`]
//! validates this, rejecting malformed or truncated buffers).
//!
//! ## Bit-identity with the CSR kernels
//!
//! [`Csr::row_matvec`] accumulates into `y[c]` over ascending input row
//! `r`, skipping rows where the activation is exactly `0.0` (and CSR
//! never stores a zero value). The gather kernels here visit each
//! column's entries in ascending `r` (groups ascend, in-group offsets
//! ascend) and skip both zero activations and padded zero values, so
//! per output column the f32 additions happen in the identical order on
//! the identical terms — the outputs are bit-identical, which is what
//! lets `bench_serve` and the serve CLI diff token streams across
//! backends.

use crate::linalg::{Csr, Matrix};
use anyhow::{ensure, Result};

/// Packed N:M sparse matrix (`rows` = input dim, `cols` = output dim).
#[derive(Clone, Debug, PartialEq)]
pub struct NmPacked {
    pub rows: usize,
    pub cols: usize,
    pub n: usize,
    pub m: usize,
    /// Bit width of one packed in-group index (1, 2, 4, or 8).
    bits: usize,
    /// Slot values, `cols * (rows / m) * n` entries, column-major.
    values: Vec<f32>,
    /// Bit-packed in-group indices, `ceil(slots * bits / 64)` words.
    idx: Vec<u64>,
}

/// Index width for group size `m`: `ceil(log2(m))` rounded up to a
/// power of two, so `64 % bits == 0` and no index straddles a word.
fn index_bits(m: usize) -> usize {
    let need = usize::BITS as usize - (m - 1).leading_zeros() as usize;
    match need {
        0 | 1 => 1,
        2 => 2,
        3 | 4 => 4,
        _ => 8,
    }
}

fn idx_words(slots: usize, bits: usize) -> usize {
    (slots * bits).div_ceil(64)
}

fn validate_pattern(rows: usize, cols: usize, n: usize, m: usize) -> Result<()> {
    ensure!((2..=256).contains(&m), "N:M group size M must be in 2..=256, got {m}");
    ensure!(n <= m, "bad N:M pattern {n}:{m} — N must be <= M");
    ensure!(cols > 0, "matrix has no output columns");
    ensure!(
        rows % m == 0,
        "input dim {rows} not divisible by M={m} — layer cannot pack as {n}:{m}"
    );
    Ok(())
}

impl NmPacked {
    /// Pack a dense matrix that conforms to the N:M pattern (at most `n`
    /// nonzeros in every group of `m` consecutive rows per column, e.g.
    /// the output of `nm_project`). Errors on shape or pattern
    /// violations instead of panicking — the serving path packs
    /// untrusted checkpoints and must refuse, not abort.
    pub fn from_dense(w: &Matrix, n: usize, m: usize) -> Result<NmPacked> {
        validate_pattern(w.rows, w.cols, n, m)?;
        let (bits, groups) = (index_bits(m), w.rows / m);
        let slots = w.cols * groups * n;
        let mut values = vec![0.0f32; slots];
        let mut idx = vec![0u64; idx_words(slots, bits)];
        let mut entries: Vec<(usize, f32)> = Vec::with_capacity(m);
        for c in 0..w.cols {
            for g in 0..groups {
                let g0 = g * m;
                entries.clear();
                for j in 0..m {
                    let v = w.at(g0 + j, c);
                    if v != 0.0 {
                        entries.push((j, v));
                    }
                }
                ensure!(
                    entries.len() <= n,
                    "column {c} rows {g0}..{} hold {} nonzeros — not {n}:{m}-sparse",
                    g0 + m,
                    entries.len()
                );
                pad_group(&mut entries, n, m);
                store_group(&mut values, &mut idx, bits, (c * groups + g) * n, &entries);
            }
        }
        Ok(NmPacked { rows: w.rows, cols: w.cols, n, m, bits, values, idx })
    }

    /// Pack directly from a CSR matrix (same validation as
    /// [`NmPacked::from_dense`], without materializing a dense copy).
    pub fn from_csr(a: &Csr, n: usize, m: usize) -> Result<NmPacked> {
        validate_pattern(a.rows, a.cols, n, m)?;
        let (bits, groups) = (index_bits(m), a.rows / m);
        // bucket entries by (column, group); ascending-row iteration
        // keeps every bucket's in-group offsets ascending
        let mut buckets: Vec<Vec<(usize, f32)>> = vec![Vec::new(); a.cols * groups];
        for r in 0..a.rows {
            let (g, j) = (r / m, r % m);
            for i in a.row_range(r) {
                let v = a.values[i];
                if v != 0.0 {
                    buckets[a.indices[i] as usize * groups + g].push((j, v));
                }
            }
        }
        let slots = a.cols * groups * n;
        let mut values = vec![0.0f32; slots];
        let mut idx = vec![0u64; idx_words(slots, bits)];
        for (b, entries) in buckets.iter_mut().enumerate() {
            let (c, g) = (b / groups, b % groups);
            ensure!(
                entries.len() <= n,
                "column {c} rows {}..{} hold {} nonzeros — not {n}:{m}-sparse",
                g * m,
                g * m + m,
                entries.len()
            );
            pad_group(entries, n, m);
            store_group(&mut values, &mut idx, bits, b * n, entries);
        }
        Ok(NmPacked { rows: a.rows, cols: a.cols, n, m, bits, values, idx })
    }

    /// Reassemble from raw buffers (the wire/mmap direction), validating
    /// everything a hostile or truncated input could violate: buffer
    /// lengths must match the shape exactly, every in-group index must
    /// be `< m` and strictly ascending within its group, and bits past
    /// the last packed index must be zero (canonical form — equal
    /// matrices have equal buffers).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        n: usize,
        m: usize,
        values: Vec<f32>,
        idx: Vec<u64>,
    ) -> Result<NmPacked> {
        validate_pattern(rows, cols, n, m)?;
        let (bits, groups) = (index_bits(m), rows / m);
        let slots = cols * groups * n;
        ensure!(
            values.len() == slots,
            "value buffer holds {} slots, shape needs {slots}",
            values.len()
        );
        let want = idx_words(slots, bits);
        ensure!(idx.len() == want, "index buffer holds {} words, shape needs {want}", idx.len());
        let used_bits = slots * bits;
        if used_bits % 64 != 0 {
            let tail = idx[used_bits >> 6] >> (used_bits & 63);
            ensure!(tail == 0, "index buffer carries nonzero bits past the last packed slot");
        }
        let p = NmPacked { rows, cols, n, m, bits, values, idx };
        for c in 0..cols {
            for g in 0..groups {
                let mut prev: Option<usize> = None;
                for j in 0..n {
                    let gi = p.idx_at((c * groups + g) * n + j);
                    ensure!(gi < m, "in-group index {gi} out of range for M={m}");
                    if let Some(prev) = prev {
                        ensure!(
                            gi > prev,
                            "in-group indices must be strictly ascending \
                             (column {c}, group {g}: {prev} then {gi})"
                        );
                    }
                    prev = Some(gi);
                }
            }
        }
        Ok(p)
    }

    /// In-group index of slot `s`. `bits` divides 64, so the index sits
    /// wholly inside one word.
    #[inline]
    fn idx_at(&self, s: usize) -> usize {
        let off = s * self.bits;
        (self.idx[off >> 6] >> (off & 63)) as usize & ((1 << self.bits) - 1)
    }

    pub fn groups(&self) -> usize {
        self.rows / self.m
    }

    /// Stored nonzeros (padding slots hold `0.0` and do not count).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| **v != 0.0).count()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Bytes of the packed representation (f32 slot values + bit-packed
    /// index words). For 2:4 this is 4.25 bytes per kept weight vs CSR's
    /// 8 + indptr.
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.idx.len() * 8
    }

    pub fn to_dense(&self) -> Matrix {
        let groups = self.groups();
        let mut out = Matrix::zeros(self.rows, self.cols);
        for c in 0..self.cols {
            for g in 0..groups {
                for j in 0..self.n {
                    let s = (c * groups + g) * self.n + j;
                    let v = self.values[s];
                    if v != 0.0 {
                        *out.at_mut(g * self.m + self.idx_at(s), c) = v;
                    }
                }
            }
        }
        out
    }

    /// y = x W for a single activation row x (len == `rows`) — the
    /// KV-cache decode shape. Gather form: one output column at a time,
    /// streaming its `groups * n` value slots sequentially; each `y[c]`
    /// is written exactly once. Bit-identical to [`Csr::row_matvec`]
    /// (see the module doc for the accumulation-order argument).
    pub fn row_matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        let groups = self.groups();
        let mask = (1usize << self.bits) - 1;
        let mut y = vec![0.0f32; self.cols];
        for (c, yc) in y.iter_mut().enumerate() {
            let base = c * groups * self.n;
            let mut acc = 0.0f32;
            for g in 0..groups {
                let g0 = g * self.m;
                for j in 0..self.n {
                    let s = base + g * self.n + j;
                    let v = self.values[s];
                    if v == 0.0 {
                        continue; // padding slot — CSR stores no zeros
                    }
                    let off = s * self.bits;
                    let xv = x[g0 + ((self.idx[off >> 6] >> (off & 63)) as usize & mask)];
                    if xv == 0.0 {
                        continue; // match the CSR zero-activation skip
                    }
                    acc += xv * v;
                }
            }
            *yc = acc;
        }
        y
    }

    /// Dense @ packed: Y = X W (shape `x.cols == rows`) — the batched
    /// decode / prefill shape. Each output row reproduces the
    /// single-row kernel exactly, so this is bit-identical to
    /// [`Csr::left_matmul`] row by row.
    pub fn left_matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.rows);
        let mut y = Matrix::zeros(x.rows, self.cols);
        for t in 0..x.rows {
            y.row_mut(t).copy_from_slice(&self.row_matvec(x.row(t)));
        }
        y
    }
}

/// Extend an ascending `(in-group index, value)` list to exactly `n`
/// entries by inserting `0.0` at the smallest unused offsets, keeping
/// the index order strictly ascending.
fn pad_group(entries: &mut Vec<(usize, f32)>, n: usize, m: usize) {
    if entries.len() == n {
        return;
    }
    let mut used = [false; 256];
    for &(j, _) in entries.iter() {
        used[j] = true;
    }
    for (j, used) in used.iter().enumerate().take(m) {
        if entries.len() == n {
            break;
        }
        if !used {
            entries.push((j, 0.0));
        }
    }
    entries.sort_unstable_by_key(|&(j, _)| j);
}

/// Write one padded group's `n` entries at slot offset `s0`.
fn store_group(
    values: &mut [f32],
    idx: &mut [u64],
    bits: usize,
    s0: usize,
    entries: &[(usize, f32)],
) {
    for (j, &(gi, v)) in entries.iter().enumerate() {
        let s = s0 + j;
        values[s] = v;
        let off = s * bits;
        idx[off >> 6] |= (gi as u64) << (off & 63);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::projection::nm_project;
    use crate::util::Rng;

    fn random_nm(rows: usize, cols: usize, n: usize, m: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        nm_project(&Matrix::randn(rows, cols, &mut rng), n, m)
    }

    #[test]
    fn bit_widths() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(4), 2);
        assert_eq!(index_bits(8), 4);
        assert_eq!(index_bits(16), 4);
        assert_eq!(index_bits(17), 8);
        assert_eq!(index_bits(256), 8);
    }

    #[test]
    fn dense_roundtrip_24() {
        let w = random_nm(16, 6, 2, 4, 0);
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        assert_eq!(p.to_dense(), w);
        assert_eq!(p.nnz(), w.nnz());
        assert_eq!(p.groups(), 4);
    }

    #[test]
    fn csr_roundtrip_matches_dense_packing() {
        let w = random_nm(24, 5, 4, 8, 1);
        let from_dense = NmPacked::from_dense(&w, 4, 8).unwrap();
        let from_csr = NmPacked::from_csr(&Csr::from_dense(&w), 4, 8).unwrap();
        // canonical packing: both directions produce identical buffers
        assert_eq!(from_dense, from_csr);
        assert_eq!(from_csr.to_dense(), w);
    }

    #[test]
    fn deficient_groups_pad_and_roundtrip() {
        // one group entirely zero, one with a single nonzero: both pad
        let mut w = Matrix::zeros(8, 1);
        w.data[5] = 3.0; // second group of rows 4..8
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        assert_eq!(p.nnz(), 1);
        assert_eq!(p.to_dense(), w);
        // kernels still match CSR on padded groups
        let csr = Csr::from_dense(&w);
        let x: Vec<f32> = (0..8).map(|i| i as f32 - 3.0).collect();
        assert_eq!(p.row_matvec(&x), csr.row_matvec(&x));
    }

    #[test]
    fn nonconformant_dense_rejected() {
        let mut rng = Rng::new(2);
        let dense = Matrix::randn(16, 4, &mut rng); // ~all nonzero
        let err = NmPacked::from_dense(&dense, 2, 4).unwrap_err().to_string();
        assert!(err.contains("not 2:4-sparse"), "{err}");
        let err = NmPacked::from_csr(&Csr::from_dense(&dense), 2, 4).unwrap_err().to_string();
        assert!(err.contains("not 2:4-sparse"), "{err}");
    }

    #[test]
    fn bad_shapes_rejected() {
        let w = Matrix::zeros(10, 3); // 10 % 4 != 0
        assert!(NmPacked::from_dense(&w, 2, 4).is_err());
        let w = Matrix::zeros(8, 3);
        assert!(NmPacked::from_dense(&w, 5, 4).is_err()); // n > m
        assert!(NmPacked::from_dense(&w, 1, 1).is_err()); // m < 2
        assert!(NmPacked::from_dense(&w, 2, 512).is_err()); // m > 256
    }

    #[test]
    fn from_parts_roundtrip_and_rejections() {
        let w = random_nm(8, 3, 2, 4, 3);
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        let ok = NmPacked::from_parts(8, 3, 2, 4, p.values.clone(), p.idx.clone()).unwrap();
        assert_eq!(ok, p);

        // truncated value buffer
        let mut v = p.values.clone();
        v.pop();
        assert!(NmPacked::from_parts(8, 3, 2, 4, v, p.idx.clone()).is_err());
        // truncated index buffer
        assert!(NmPacked::from_parts(8, 3, 2, 4, p.values.clone(), Vec::new()).is_err());
        // non-ascending in-group indices (slot 0 and 1 both index 0)
        let zeroed = vec![0u64; p.idx.len()];
        let err = NmPacked::from_parts(8, 3, 2, 4, p.values.clone(), zeroed)
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly ascending"), "{err}");
        // out-of-range index: M=3 packs at 2 bits, so the value 3 fits
        // the field but exceeds the group
        let w3 = random_nm(6, 1, 1, 3, 4);
        let p3 = NmPacked::from_dense(&w3, 1, 3).unwrap();
        let mut bad = p3.idx.clone();
        bad[0] |= 0b11; // slot 0 -> index 3 >= m
        let err = NmPacked::from_parts(6, 1, 1, 3, p3.values.clone(), bad)
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
        // garbage past the last packed slot breaks canonical form
        let mut tail = p3.idx.clone();
        tail[0] |= 1u64 << 63;
        assert!(NmPacked::from_parts(6, 1, 1, 3, p3.values.clone(), tail).is_err());
    }

    #[test]
    fn row_matvec_bit_identical_to_csr() {
        for (n, m, seed) in [(2usize, 4usize, 5u64), (1, 2, 6), (4, 8, 7)] {
            let w = random_nm(32, 9, n, m, seed);
            let p = NmPacked::from_dense(&w, n, m).unwrap();
            let csr = Csr::from_dense(&w);
            let mut rng = Rng::new(seed + 100);
            let mut x = rng.gaussian_vec(32);
            x[3] = 0.0; // exercise the zero-activation skip
            x[17] = 0.0;
            let got = p.row_matvec(&x);
            let want = csr.row_matvec(&x);
            assert_eq!(got, want, "{n}:{m} gather diverged from CSR bitwise");
        }
    }

    #[test]
    fn left_matmul_bit_identical_to_csr() {
        let w = random_nm(16, 7, 2, 4, 8);
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        let csr = Csr::from_dense(&w);
        let mut rng = Rng::new(9);
        let x = Matrix::randn(5, 16, &mut rng);
        assert_eq!(p.left_matmul(&x), csr.left_matmul(&x));
    }

    #[test]
    fn bytes_accounting() {
        let w = random_nm(128, 64, 2, 4, 10);
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        let slots = 64 * 32 * 2;
        assert_eq!(p.bytes(), slots * 4 + (slots * 2).div_ceil(64) * 8);
        // 2:4 packs to ~4.25 bytes/weight vs CSR's 8 + indptr
        assert!(p.bytes() < Csr::from_dense(&w).bytes());
        // and half + eps of the dense f32 footprint
        assert!(p.bytes() < 128 * 64 * 4 * 9 / 16);
    }

    #[test]
    fn density_counts_padding_as_zero() {
        let w = Matrix::zeros(8, 2);
        let p = NmPacked::from_dense(&w, 2, 4).unwrap();
        assert_eq!(p.nnz(), 0);
        assert_eq!(p.density(), 0.0);
        assert_eq!(p.row_matvec(&[1.0; 8]), vec![0.0; 2]);
    }
}
