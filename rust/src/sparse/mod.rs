//! `sparse` — the packed semi-structured N:M weight subsystem: the
//! serving-side format for what ALPS prunes.
//!
//! The paper's headline deployable artifact is N:M sparsity (the 2:4
//! results): exactly N kept weights in every group of M consecutive
//! inputs of each output column. The pruning tier already *produces*
//! those masks ([`crate::pruning::projection::nm_project`],
//! `bench_table3_nm`); this module lets the serving tier *execute* them
//! as N:M instead of paying generic-CSR bookkeeping for a format whose
//! whole point is fixed, predictable structure:
//!
//! * [`packed`] — [`NmPacked`]: values stored contiguously per output
//!   column, in-group indices bit-packed (2 bits each for 2:4), no
//!   indptr, perfectly strided group-wise gather kernels. Validated
//!   conversions from masked dense and from [`crate::linalg::Csr`],
//!   plus [`NmPacked::from_parts`] for untrusted buffers. Kernels are
//!   **bit-identical** to the CSR kernels (same ascending accumulation
//!   order — the repo's standing exactness discipline).
//! * [`model`] — [`NmModel`]: every prunable matrix packed, with a
//!   per-layer CSR fallback for non-conformant layers so mixed
//!   checkpoints serve. Implements [`crate::model::DecodeOps`], so the
//!   whole serve stack (decoder, batcher, TCP front-end) runs on it
//!   unchanged via `alps serve --format nm` /
//!   [`crate::serve::Engine::nm`].
//! * [`int8`] — [`Int8Model`]/[`Int8Weight`]: the quantized deployment
//!   format ([`crate::pruning::quantize`]'s int8 codes + per-column f32
//!   scales) behind the same [`crate::model::DecodeOps`] seam, served
//!   via `alps serve --format int8` / [`crate::serve::Engine::int8`].
//!   Weight bytes drop to ~25% of dense f32; the kernels are
//!   bit-identical to dense on the dequantized matrix, and a checkpoint
//!   already on the int8 grid (`examples/prune_quantize.rs`) re-loads
//!   with exact codes and ≤1-ulp scales, so its decode matches dense to
//!   ulp precision.
//!
//! `bench_serve` races dense vs CSR vs packed N:M at matched 2:4
//! sparsity, and `bench_perf_hotpath` tracks the kernel-level gap in
//! `BENCH_perf.json`.
//!
//! This is a server path: `alps-lint` rule 1 (panic-freedom) applies,
//! and conversion errors surface as `Result`s — a malformed checkpoint
//! must be refused, not abort the process.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod int8;
pub mod model;
pub mod packed;

pub use int8::{Int8Model, Int8Weight};
pub use model::{NmModel, NmWeight};
pub use packed::NmPacked;
