//! Model-level packing: every prunable matrix held as [`NmPacked`],
//! with a per-layer CSR fallback so mixed checkpoints (some layers
//! N:M-pruned, some unstructured or dense) still serve through the same
//! backend. Implements [`DecodeOps`], so [`crate::model::Decoder`],
//! `prefill_batch`, the batcher, and the TCP front-end run unchanged.
//!
//! Exactness contract: a packed layer's kernels are bit-identical to
//! the CSR kernels on the same weights (see [`super::packed`]), and a
//! fallback layer *is* CSR — so an [`NmModel`] decode is bit-identical
//! to [`crate::model::SparseModel`] end to end, whatever mix of layers
//! packed. The integration suite pins this at the single-step,
//! `prefill_batch`, and full-generation levels.

use super::packed::NmPacked;
use crate::linalg::{Csr, Matrix};
use crate::model::{DecodeOps, Model};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// One prunable layer in the packed model: the strided N:M format when
/// the layer conforms, generic CSR otherwise.
pub enum NmWeight {
    Packed(NmPacked),
    Csr(Csr),
}

/// A model with prunable matrices packed as N:M (CSR per-layer fallback).
pub struct NmModel<'m> {
    pub model: &'m Model,
    weights: HashMap<String, NmWeight>,
    n: usize,
    m: usize,
}

impl<'m> NmModel<'m> {
    /// Pack every prunable matrix as `n`:`m`; a layer that is not
    /// N:M-conformant (or whose input dim is not divisible by `m`)
    /// falls back to CSR instead of failing the whole model, so a
    /// mixed checkpoint serves. [`NmModel::packed_layers`] reports how
    /// many layers took the packed path.
    pub fn from_model(model: &'m Model, n: usize, m: usize) -> Result<Self> {
        let mut weights = HashMap::new();
        for name in model.prunable_names() {
            let w = model.weights.matrix(&name)?;
            let weight = match NmPacked::from_dense(&w, n, m) {
                Ok(p) => NmWeight::Packed(p),
                Err(_) => NmWeight::Csr(Csr::from_dense(&w)),
            };
            weights.insert(name, weight);
        }
        Ok(NmModel { model, weights, n, m })
    }

    /// The target pattern this model was packed against.
    pub fn pattern(&self) -> (usize, usize) {
        (self.n, self.m)
    }

    /// Layers that took the packed N:M path (the rest serve as CSR).
    pub fn packed_layers(&self) -> usize {
        self.weights.values().filter(|w| matches!(w, NmWeight::Packed(_))).count()
    }

    pub fn layer_count(&self) -> usize {
        self.weights.len()
    }

    /// Weighted mean density over the prunable matrices.
    pub fn density(&self) -> f64 {
        let (mut nnz, mut total) = (0usize, 0usize);
        for w in self.weights.values() {
            let (z, rc) = match w {
                NmWeight::Packed(p) => (p.nnz(), p.rows * p.cols),
                NmWeight::Csr(c) => (c.nnz(), c.rows * c.cols),
            };
            nnz += z;
            total += rc;
        }
        nnz as f64 / total.max(1) as f64
    }

    /// Memory footprint of the packed prunable weights in bytes
    /// (packed-or-CSR per layer) vs dense f32.
    pub fn bytes_packed_vs_dense(&self) -> (usize, usize) {
        let (mut packed, mut dense) = (0usize, 0usize);
        for w in self.weights.values() {
            let (b, rc) = match w {
                NmWeight::Packed(p) => (p.bytes(), p.rows * p.cols),
                NmWeight::Csr(c) => (c.bytes(), c.rows * c.cols),
            };
            packed += b;
            dense += rc * 4;
        }
        (packed, dense)
    }

    fn weight(&self, name: &str) -> Result<&NmWeight> {
        self.weights.get(name).ok_or_else(|| anyhow!("no packed weight for '{name}'"))
    }
}

/// Packed decode backend: the single-row gather kernel for unbatched
/// decode, `left_matmul` for batched decode steps and the multi-row
/// `Decoder::prefill_batch` passes — the same routing as the CSR
/// backend, with bit-identical results.
impl DecodeOps for NmModel<'_> {
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        match self.weight(name)? {
            NmWeight::Packed(p) => Ok(if x.rows == 1 {
                Matrix::from_vec(1, p.cols, p.row_matvec(x.row(0)))
            } else {
                p.left_matmul(x)
            }),
            NmWeight::Csr(c) => Ok(if x.rows == 1 {
                Matrix::from_vec(1, c.cols, c.row_matvec(x.row(0)))
            } else {
                c.left_matmul(x)
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;
    use crate::model::{Decoder, SparseModel};
    use crate::pruning::projection::nm_project;

    fn nm_pruned(seed: u64) -> Model {
        let mut m = random_model(seed);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            m.weights.set_matrix(&name, &nm_project(&w, 2, 4)).unwrap();
        }
        m
    }

    #[test]
    fn conformant_model_packs_every_layer() {
        let m = nm_pruned(30);
        let nm = NmModel::from_model(&m, 2, 4).unwrap();
        assert_eq!(nm.packed_layers(), nm.layer_count());
        assert_eq!(nm.layer_count(), m.prunable_names().len());
        assert!((nm.density() - 0.5).abs() < 0.05, "2:4 density {}", nm.density());
        let (packed, dense) = nm.bytes_packed_vs_dense();
        assert!(packed < dense * 6 / 10, "packed {packed} vs dense {dense}");
    }

    #[test]
    fn mixed_checkpoint_falls_back_per_layer() {
        // leave the dense random weights on all but one layer: only the
        // projected layer conforms, the rest must serve as CSR
        let mut m = random_model(31);
        let name = "blocks.0.mlp.w1";
        let w = m.weights.matrix(name).unwrap();
        m.weights.set_matrix(name, &nm_project(&w, 2, 4)).unwrap();
        let nm = NmModel::from_model(&m, 2, 4).unwrap();
        assert_eq!(nm.packed_layers(), 1);
        assert_eq!(nm.layer_count(), m.prunable_names().len());
        // and the mixed backend still decodes bit-identically to CSR
        let sdec = Decoder::new(&m, SparseModel::from_model(&m).unwrap()).unwrap();
        let ndec = Decoder::new(&m, NmModel::from_model(&m, 2, 4).unwrap()).unwrap();
        let mut sc = sdec.new_cache();
        let mut nc = ndec.new_cache();
        for &tok in &[2u16, 7, 1, 9] {
            let a = sdec.step(&mut sc, tok).unwrap();
            let b = ndec.step(&mut nc, tok).unwrap();
            assert_eq!(a, b, "mixed packed/CSR decode diverged from CSR");
        }
    }

    #[test]
    fn packed_decode_bit_identical_to_csr() {
        let m = nm_pruned(32);
        let sdec = Decoder::new(&m, SparseModel::from_model(&m).unwrap()).unwrap();
        let ndec = Decoder::new(&m, NmModel::from_model(&m, 2, 4).unwrap()).unwrap();
        let ids = [2u16, 7, 1, 9, 4, 3];
        // batched prefill, then stepwise decode: exact equality throughout
        let mut sc = sdec.new_cache();
        let mut nc = ndec.new_cache();
        let a = sdec.prefill_batch(&mut sc, &ids).unwrap();
        let b = ndec.prefill_batch(&mut nc, &ids).unwrap();
        assert_eq!(a, b, "prefill_batch diverged bitwise");
        for &tok in &[5u16, 11, 0] {
            let a = sdec.step(&mut sc, tok).unwrap();
            let b = ndec.step(&mut nc, tok).unwrap();
            assert_eq!(a, b, "decode step diverged bitwise");
        }
    }

    #[test]
    fn missing_weight_rejected() {
        let m = nm_pruned(33);
        let nm = NmModel::from_model(&m, 2, 4).unwrap();
        assert!(nm.apply("nope", &Matrix::zeros(1, 16)).is_err());
    }

    #[test]
    fn pattern_is_recorded() {
        let m = nm_pruned(34);
        let nm = NmModel::from_model(&m, 2, 4).unwrap();
        assert_eq!(nm.pattern(), (2, 4));
    }
}
