//! Int8 quantized decode backend — serve the weights
//! [`crate::pruning::quantize`] produces.
//!
//! [`Int8Weight`] stores a prunable matrix as int8 codes + per-output-
//! column f32 scales (the `QuantizedWeights` layout), and decodes with
//! f32 accumulators: the kernels compute `x[k] * (code as f32 * scale)`
//! per term, which is exactly the dequantized f32 weight — bit-identical
//! to the dense kernels running on [`Int8Weight::dequantize`]'s output,
//! with the accumulation kept in the repo's standard k-ascending order.
//! On a checkpoint whose weights sit on the int8 grid (what
//! `examples/prune_quantize.rs` writes), load-time re-quantization
//! recovers the codes *exactly* and the scales to within 1 ulp — exactly
//! when the scale is a power of two, since f32 `(127*s)/127` is not an
//! identity for general `s` — so decode matches dense to ulp precision
//! and greedy token streams agree. Weight bytes drop to ~25% of dense
//! f32 (1 byte/code + one f32 scale per column), which is what
//! weight-bandwidth-bound decode throughput actually buys.
//!
//! [`Int8Model`] packs every prunable matrix and implements
//! [`crate::model::DecodeOps`], so the whole serve stack (decoder,
//! batcher, TCP front-end) runs on it unchanged via
//! `alps serve --format int8` / [`crate::serve::Engine::int8`].
//!
//! This is a server path: `alps-lint` rule 1 (panic-freedom) applies —
//! malformed shapes surface as `Result`s, never aborts.

use crate::linalg::Matrix;
use crate::model::{DecodeOps, Model};
use crate::pruning::quantize::QuantizedWeights;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;

/// One prunable layer as int8 codes (row-major `[rows, cols]`) with a
/// per-output-column f32 scale.
pub struct Int8Weight {
    pub rows: usize,
    pub cols: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
}

impl Int8Weight {
    /// Adopt a [`QuantizedWeights`], validating its buffer shapes (the
    /// quantizer upholds them, but checkpoints may arrive from anywhere).
    pub fn from_quantized(q: QuantizedWeights) -> Result<Int8Weight> {
        ensure!(
            q.codes.len() == q.rows * q.cols,
            "int8 codes length {} != {}x{}",
            q.codes.len(),
            q.rows,
            q.cols
        );
        ensure!(
            q.scales.len() == q.cols,
            "int8 scales length {} != cols {}",
            q.scales.len(),
            q.cols
        );
        Ok(Int8Weight { rows: q.rows, cols: q.cols, codes: q.codes, scales: q.scales })
    }

    /// Symmetric per-column int8 quantization of a dense matrix. For a
    /// matrix already on the int8 grid (a `prune_quantize` checkpoint)
    /// this recovers the codes exactly and the scales to within 1 ulp
    /// (exactly when the scale is a power of two — see the module docs).
    pub fn from_dense(w: &Matrix) -> Result<Int8Weight> {
        Int8Weight::from_quantized(QuantizedWeights::quantize(w))
    }

    /// Stored bytes: one per code plus one f32 scale per column.
    pub fn bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len()
    }

    /// Surviving (nonzero-code) weight count.
    pub fn nnz(&self) -> usize {
        self.codes.iter().filter(|c| **c != 0).count()
    }

    /// Dense f32 reconstruction — the exact values the decode kernels
    /// multiply by (`code as f32 * scale`), for tests and fallbacks.
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                m.data[r * self.cols + c] = self.codes[r * self.cols + c] as f32 * self.scales[c];
            }
        }
        m
    }

    /// y += x @ W for one activation row (`x.len() == rows`), into a
    /// pre-zeroed (or partial) output row of length `cols`. Terms are
    /// `x[k] * (code as f32 * scale)` accumulated k-ascending with the
    /// zero-activation skip — the same per-element chain as the dense
    /// kernels on the dequantized matrix, hence bit-identical to them.
    fn accumulate_row(&self, x: &[f32], y: &mut [f32]) {
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let crow = &self.codes[k * self.cols..(k + 1) * self.cols];
            for ((yv, &code), &s) in y.iter_mut().zip(crow).zip(&self.scales) {
                *yv += xv * (code as f32 * s);
            }
        }
    }

    /// y = x @ W for a single activation row — the KV-cache decode shape.
    pub fn row_matvec(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0f32; self.cols];
        self.accumulate_row(x, &mut y);
        y
    }

    /// Y = X @ W for a multi-row activation batch (batched decode steps
    /// and `prefill_batch`).
    pub fn left_matmul(&self, x: &Matrix) -> Matrix {
        debug_assert_eq!(x.cols, self.rows);
        let mut out = Matrix::zeros(x.rows, self.cols);
        for r in 0..x.rows {
            let dst = &mut out.data[r * self.cols..(r + 1) * self.cols];
            self.accumulate_row(x.row(r), dst);
        }
        out
    }
}

/// A model with every prunable matrix quantized to int8 at load time.
pub struct Int8Model<'m> {
    pub model: &'m Model,
    weights: HashMap<String, Int8Weight>,
}

impl<'m> Int8Model<'m> {
    /// Quantize every prunable matrix (dense tensors untouched). On a
    /// `prune_quantize`-produced checkpoint the stored f32 weights are
    /// already on the int8 grid, so this recovers their codes exactly
    /// (and their scales to ≤1 ulp — see the module docs).
    pub fn from_model(model: &'m Model) -> Result<Self> {
        let mut weights = HashMap::new();
        for name in model.prunable_names() {
            let w = model.weights.matrix(&name)?;
            weights.insert(name, Int8Weight::from_dense(&w)?);
        }
        Ok(Int8Model { model, weights })
    }

    pub fn layer_count(&self) -> usize {
        self.weights.len()
    }

    /// Weighted mean density (nonzero codes) over the prunable matrices.
    pub fn density(&self) -> f64 {
        let (mut nnz, mut total) = (0usize, 0usize);
        for w in self.weights.values() {
            nnz += w.nnz();
            total += w.rows * w.cols;
        }
        nnz as f64 / total.max(1) as f64
    }

    /// Memory footprint of the int8 prunable weights in bytes (codes +
    /// per-column scales) vs dense f32 — ~25% for any non-trivial rows.
    pub fn bytes_int8_vs_dense(&self) -> (usize, usize) {
        let (mut int8, mut dense) = (0usize, 0usize);
        for w in self.weights.values() {
            int8 += w.bytes();
            dense += w.rows * w.cols * 4;
        }
        (int8, dense)
    }

    fn weight(&self, name: &str) -> Result<&Int8Weight> {
        self.weights.get(name).ok_or_else(|| anyhow!("no int8 weight for '{name}'"))
    }
}

/// Int8 decode backend: the single-row kernel for unbatched decode,
/// `left_matmul` for batched decode steps and multi-row prefill — the
/// same routing as the CSR and packed N:M backends.
impl DecodeOps for Int8Model<'_> {
    fn apply(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        let w = self.weight(name)?;
        ensure!(
            x.cols == w.rows,
            "int8 weight '{name}': activation dim {} vs weight rows {}",
            x.cols,
            w.rows
        );
        Ok(if x.rows == 1 {
            Matrix::from_vec(1, w.cols, w.row_matvec(x.row(0)))
        } else {
            w.left_matmul(x)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::model::transformer::testutil::random_model;
    use crate::model::{Decoder, DenseOps};
    use crate::util::Rng;

    /// Put every prunable matrix of a random model onto the int8 grid —
    /// the state a `prune_quantize` checkpoint arrives in — with the
    /// scales snapped to powers of two so load-time scale recovery is
    /// bitwise-exact (f32 `(127*s)/127` is not an identity for general
    /// `s`; general grids recover to ≤1 ulp, covered separately).
    fn grid_model(seed: u64) -> Model {
        let mut m = random_model(seed);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let mut q = QuantizedWeights::quantize(&w);
            for s in &mut q.scales {
                *s = s.log2().round().exp2();
            }
            m.weights.set_matrix(&name, &q.dequantize()).unwrap();
        }
        m
    }

    #[test]
    fn kernels_bit_identical_to_dequantized_dense() {
        let mut rng = Rng::new(40);
        for &(rows, cols) in &[(16, 24), (33, 7), (65, 70)] {
            let w = Matrix::randn(rows, cols, &mut rng);
            let q = Int8Weight::from_dense(&w).unwrap();
            let deq = q.dequantize();
            let x = rng.gaussian_vec(rows);
            let xm = Matrix::from_vec(1, rows, x.clone());
            // single-row kernel vs dense matmul on the dequantized matrix
            assert_eq!(q.row_matvec(&x), matmul(&xm, &deq).data, "{rows}x{cols}");
            // multi-row kernel too
            let xb = Matrix::randn(5, rows, &mut rng);
            assert_eq!(q.left_matmul(&xb).data, matmul(&xb, &deq).data, "{rows}x{cols} batch");
        }
    }

    #[test]
    fn general_grid_recovers_codes_exactly_values_to_ulp() {
        // quantize -> dequantize -> re-quantize: the codes are a fixed
        // point; the scales (and so the values) recover to within 1 ulp
        // because f32 (127*s)/127 can round one step off s
        let mut rng = Rng::new(41);
        let w = Matrix::randn(40, 12, &mut rng);
        let q1 = Int8Weight::from_dense(&w).unwrap();
        let once = q1.dequantize();
        let q2 = Int8Weight::from_dense(&once).unwrap();
        assert_eq!(q1.codes, q2.codes);
        for (a, b) in once.data.iter().zip(&q2.dequantize().data) {
            assert!((a - b).abs() <= 3.0e-7 * a.abs(), "{a} vs {b}");
        }
    }

    #[test]
    fn power_of_two_grid_round_trips_exactly() {
        // with power-of-two scales, 127*s and (127*s)/127 are both exact,
        // so the whole grid is a bitwise fixed point of re-quantization —
        // the property grid_model relies on
        let mut rng = Rng::new(47);
        let mut q = QuantizedWeights::quantize(&Matrix::randn(40, 12, &mut rng));
        for s in &mut q.scales {
            *s = s.log2().round().exp2();
        }
        let once = Int8Weight::from_quantized(q).unwrap().dequantize();
        let twice = Int8Weight::from_dense(&once).unwrap().dequantize();
        assert_eq!(once.data, twice.data);
    }

    #[test]
    fn int8_decode_bit_identical_to_dense_on_grid_checkpoint() {
        let m = grid_model(42);
        let ddec = Decoder::new(&m, DenseOps::new(&m).unwrap()).unwrap();
        let qdec = Decoder::new(&m, Int8Model::from_model(&m).unwrap()).unwrap();
        let ids = [2u16, 7, 1, 9, 4, 3];
        // batched prefill, then stepwise decode: exact equality throughout
        let mut dc = ddec.new_cache();
        let mut qc = qdec.new_cache();
        let a = ddec.prefill_batch(&mut dc, &ids).unwrap();
        let b = qdec.prefill_batch(&mut qc, &ids).unwrap();
        assert_eq!(a, b, "prefill_batch diverged bitwise");
        for &tok in &[5u16, 11, 0] {
            let a = ddec.step(&mut dc, tok).unwrap();
            let b = qdec.step(&mut qc, tok).unwrap();
            assert_eq!(a, b, "decode step diverged bitwise");
        }
    }

    #[test]
    fn weight_bytes_about_a_quarter_of_dense() {
        // 1 byte/code + 4 bytes/column scale: 256 rows => 25.4% of dense
        let mut rng = Rng::new(43);
        let w = Matrix::randn(256, 64, &mut rng);
        let q = Int8Weight::from_dense(&w).unwrap();
        let dense = 256 * 64 * 4;
        let ratio = q.bytes() as f64 / dense as f64;
        assert!((0.25..0.26).contains(&ratio), "ratio {ratio}");
        // model level: strictly under dense, and under CSR-at-full-density
        let m = grid_model(44);
        let im = Int8Model::from_model(&m).unwrap();
        let (int8, dense) = im.bytes_int8_vs_dense();
        assert!(int8 < dense / 3, "int8 {int8} vs dense {dense}");
        assert_eq!(im.layer_count(), m.prunable_names().len());
    }

    #[test]
    fn missing_and_misshapen_inputs_rejected() {
        let m = grid_model(45);
        let im = Int8Model::from_model(&m).unwrap();
        assert!(im.apply("nope", &Matrix::zeros(1, 16)).is_err());
        // wrong activation width must error, not abort
        assert!(im.apply("blocks.0.attn.wq", &Matrix::zeros(1, 7)).is_err());
        // malformed quantized buffers are refused
        let bad = QuantizedWeights { rows: 4, cols: 4, codes: vec![0; 3], scales: vec![1.0; 4] };
        assert!(Int8Weight::from_quantized(bad).is_err());
        let bad2 = QuantizedWeights { rows: 2, cols: 3, codes: vec![0; 6], scales: vec![1.0; 2] };
        assert!(Int8Weight::from_quantized(bad2).is_err());
    }

    #[test]
    fn pruned_zeros_survive_quantization() {
        let mut rng = Rng::new(46);
        let mut w = Matrix::randn(20, 10, &mut rng);
        for (i, v) in w.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let q = Int8Weight::from_dense(&w).unwrap();
        let deq = q.dequantize();
        for (orig, got) in w.data.iter().zip(&deq.data) {
            if *orig == 0.0 {
                assert_eq!(*got, 0.0);
            }
        }
        // nnz counts only surviving codes; density is its model-level ratio
        assert!(q.nnz() <= 200 / 3 + 1, "nnz {}", q.nnz());
        let d = Int8Model::from_model(&grid_model(46)).unwrap().density();
        assert!(d > 0.0 && d <= 1.0, "density {d}");
    }
}
