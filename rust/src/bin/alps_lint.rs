//! `alps_lint` — run the repo's static-analysis gate over `rust/src`.
//!
//! ```text
//! cargo run --bin alps_lint                         # gate: exit 0/1
//! cargo run --bin alps_lint -- --write-protocol-lock  # refresh manifest
//! cargo run --bin alps_lint -- --src DIR --protocol-lock FILE
//! ```
//!
//! The gate lexes every `.rs` file under the source root and enforces
//! the four invariants documented in [`alps::lint`]: panic-freedom and
//! lock discipline in server paths, wire-protocol conformance against
//! `PROTOCOL.lock`, and metric-naming conformance against the obs
//! naming table. Findings print one per line as
//! `path:line: [rule] message`; any finding exits 1.
//!
//! `--write-protocol-lock` recomputes the codec-layout fingerprint and
//! rewrites the manifest's `version`/`layout` lines — refusing when the
//! layout drifted but `FRAME_VERSION` did not change, so protocol
//! revisions stay deliberate.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use alps::lint::{self, wire, SourceFile};

fn main() -> ExitCode {
    let mut src_dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let mut lock_path = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../PROTOCOL.lock"));
    let mut write_lock = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--write-protocol-lock" => write_lock = true,
            "--src" => match args.next() {
                Some(v) => src_dir = PathBuf::from(v),
                None => return usage("--src needs a directory"),
            },
            "--protocol-lock" => match args.next() {
                Some(v) => lock_path = PathBuf::from(v),
                None => return usage("--protocol-lock needs a file"),
            },
            "--help" | "-h" => {
                println!(
                    "alps_lint: static-analysis gate (see rust/src/lint/mod.rs)\n\
                     usage: alps_lint [--src DIR] [--protocol-lock FILE] [--write-protocol-lock]"
                );
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument '{other}'")),
        }
    }

    let mut files = Vec::new();
    if let Err(e) = collect(&src_dir, &src_dir, &mut files) {
        eprintln!("alps_lint: walking {}: {e}", src_dir.display());
        return ExitCode::FAILURE;
    }
    files.sort_by(|a, b| a.path.cmp(&b.path));
    if files.is_empty() {
        eprintln!("alps_lint: no .rs files under {}", src_dir.display());
        return ExitCode::FAILURE;
    }

    if write_lock {
        return refresh_manifest(&files, &lock_path);
    }

    let lock_text = std::fs::read_to_string(&lock_path).ok();
    if let Some(t) = &lock_text {
        if t.lines().any(|l| l.trim() == "layout pending") {
            eprintln!(
                "alps_lint: note: PROTOCOL.lock layout is 'pending' — run with \
                 --write-protocol-lock on a machine with a toolchain to pin the codec fingerprint"
            );
        }
    }
    let findings = lint::check_sources(&files, lock_text.as_deref());
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        eprintln!("alps_lint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("alps_lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("alps_lint: {msg} (try --help)");
    ExitCode::FAILURE
}

/// Recursively collect `.rs` files as `/`-separated paths relative to
/// `root`. The lint tree excludes itself (`lint/`, `bin/`): its unit
/// tests embed deliberately-bad fixture snippets.
fn collect(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        if rel == "lint" || rel == "bin" || rel.starts_with("lint/") || rel.starts_with("bin/") {
            continue;
        }
        if path.is_dir() {
            collect(root, &path, out)?;
        } else if rel.ends_with(".rs") {
            out.push(SourceFile { path: rel, text: std::fs::read_to_string(&path)? });
        }
    }
    Ok(())
}

/// `--write-protocol-lock`: pin `version` to `FRAME_VERSION` and
/// `layout` to the current codec fingerprint.
fn refresh_manifest(files: &[SourceFile], lock_path: &Path) -> ExitCode {
    let Some(wire_src) = files.iter().find(|f| f.path == "pruning/wire.rs") else {
        eprintln!("alps_lint: pruning/wire.rs not found; cannot fingerprint the codec");
        return ExitCode::FAILURE;
    };
    let Some(framing_src) = files.iter().find(|f| f.path == "net/framing.rs") else {
        eprintln!("alps_lint: net/framing.rs not found; cannot read FRAME_VERSION");
        return ExitCode::FAILURE;
    };
    let layout = wire::layout_hash(&alps::lint::lexer::lex(&wire_src.text));
    let Some(version) = wire::frame_version(&alps::lint::lexer::lex(&framing_src.text)) else {
        eprintln!("alps_lint: FRAME_VERSION const not found in net/framing.rs");
        return ExitCode::FAILURE;
    };
    let old_text = match std::fs::read_to_string(lock_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("alps_lint: reading {}: {e}", lock_path.display());
            return ExitCode::FAILURE;
        }
    };
    if let Ok(old) = wire::parse_lock(&old_text) {
        let drifted = old.layout != "pending" && old.layout != layout;
        if drifted && old.version == version {
            eprintln!(
                "alps_lint: refusing to refresh — the codec layout drifted ({} -> {layout}) \
                 but FRAME_VERSION is still {version}. Bump FRAME_VERSION in net/framing.rs \
                 first so the protocol revision is deliberate.",
                old.layout
            );
            return ExitCode::FAILURE;
        }
    }
    let new_text = wire::rewrite_lock(&old_text, version, &layout);
    if new_text == old_text {
        eprintln!("alps_lint: {} already current", lock_path.display());
        return ExitCode::SUCCESS;
    }
    match std::fs::write(lock_path, &new_text) {
        Ok(()) => {
            eprintln!(
                "alps_lint: {} updated (version {version}, layout {layout})",
                lock_path.display()
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("alps_lint: writing {}: {e}", lock_path.display());
            ExitCode::FAILURE
        }
    }
}
