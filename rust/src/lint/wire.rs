//! Rule 3 — wire-protocol conformance.
//!
//! Ground truth is `pruning/wire.rs` (the frame payload codecs and the
//! `mod tag` constants) plus `net::framing::FRAME_VERSION`. The
//! committed `PROTOCOL.lock` manifest at the repo root records, per
//! tag: its value, its encoder and decoder symbols, and the labels its
//! payloads carry in the per-byte truncation test. The gate fails when
//! the manifest and the source disagree in either direction — adding a
//! tag without a codec, deleting a truncation label, or renaming a
//! symbol all exit non-zero.
//!
//! Layout drift: the manifest's `layout` line pins an FNV-1a
//! fingerprint of wire.rs's non-test token stream (string literals
//! excluded, so error-message edits are free). When the fingerprint
//! changes, `--write-protocol-lock` refuses to refresh the manifest
//! unless `FRAME_VERSION` was bumped too — payload drift must be a
//! deliberate protocol revision, never an accident. The committed value
//! `pending` is the bootstrap state (no toolchain has run the tool
//! yet): the gate accepts it with a notice instead of a finding.

use super::lexer::{Lexed, TokKind};
use super::{Finding, SourceFile};

#[derive(Clone, Debug, Default)]
pub struct TagRow {
    pub name: String,
    pub value: u32,
    pub encode: String,
    pub decode: String,
    pub truncation: Vec<String>,
    pub line: u32,
}

#[derive(Clone, Debug, Default)]
pub struct ProtocolLock {
    pub version: u32,
    pub truncation_test: String,
    pub rows: Vec<TagRow>,
    pub layout: String,
}

pub const LOCK_PATH: &str = "PROTOCOL.lock";

pub fn parse_lock(text: &str) -> Result<ProtocolLock, String> {
    let mut out = ProtocolLock::default();
    let mut have_version = false;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lno = idx as u32 + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut words = line.split_whitespace();
        match words.next() {
            Some("version") => {
                out.version = words
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| format!("line {lno}: bad version line"))?;
                have_version = true;
            }
            Some("truncation-test") => {
                out.truncation_test =
                    words.next().ok_or_else(|| format!("line {lno}: missing test name"))?.into();
            }
            Some("layout") => {
                out.layout =
                    words.next().ok_or_else(|| format!("line {lno}: missing layout value"))?.into();
            }
            Some("tag") => {
                let mut row = TagRow { line: lno, ..TagRow::default() };
                row.name =
                    words.next().ok_or_else(|| format!("line {lno}: missing tag name"))?.into();
                row.value = words
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| format!("line {lno}: bad tag value"))?;
                for w in words {
                    if let Some(v) = w.strip_prefix("encode=") {
                        row.encode = v.into();
                    } else if let Some(v) = w.strip_prefix("decode=") {
                        row.decode = v.into();
                    } else if let Some(v) = w.strip_prefix("truncation=") {
                        row.truncation = v.split(',').map(|s| s.to_string()).collect();
                    } else {
                        return Err(format!("line {lno}: unknown field '{w}'"));
                    }
                }
                if row.encode.is_empty() || row.decode.is_empty() || row.truncation.is_empty() {
                    return Err(format!(
                        "line {lno}: tag {} needs encode=, decode= and truncation=",
                        row.name
                    ));
                }
                out.rows.push(row);
            }
            Some(other) => return Err(format!("line {lno}: unknown directive '{other}'")),
            None => {}
        }
    }
    if !have_version {
        return Err("missing 'version' line".into());
    }
    if out.truncation_test.is_empty() {
        return Err("missing 'truncation-test' line".into());
    }
    if out.layout.is_empty() {
        return Err("missing 'layout' line".into());
    }
    Ok(out)
}

/// Extract `pub const NAME: u8 = N;` rows from the non-test `mod tag`
/// block of wire.rs tokens.
pub fn source_tags(lx: &Lexed) -> Vec<(String, u32, u32)> {
    let toks = &lx.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if !toks[i].test
            && toks[i].kind == TokKind::Ident
            && toks[i].text == "mod"
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 1].text == "tag"
        {
            let mut depth = 0usize;
            let mut j = i + 2;
            while j < toks.len() {
                let t = &toks[j];
                if t.kind == TokKind::Punct && t.text == "{" {
                    depth += 1;
                } else if t.kind == TokKind::Punct && t.text == "}" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Ident && t.text == "const" {
                    // const NAME : u8 = NUM
                    if let (Some(name), Some(num)) = (toks.get(j + 1), toks.get(j + 5)) {
                        if name.kind == TokKind::Ident && num.kind == TokKind::Num {
                            if let Ok(v) = num.text.parse() {
                                out.push((name.text.clone(), v, name.line));
                            }
                        }
                    }
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

/// `FRAME_VERSION: u8 = N` from net/framing.rs tokens.
pub fn frame_version(framing: &Lexed) -> Option<u32> {
    let toks = &framing.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident && t.text == "FRAME_VERSION" && i + 4 < toks.len() {
            let n = &toks[i + 4];
            if toks[i + 1].text == ":" && toks[i + 2].text == "u8" && n.kind == TokKind::Num {
                return n.text.parse().ok();
            }
        }
    }
    None
}

/// FNV-1a 64 fingerprint of the non-test token stream, string literals
/// excluded (message text is not layout). 16 lowercase hex digits.
pub fn layout_hash(lx: &Lexed) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |s: &str| {
        for b in s.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h ^= 0x0a;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for t in &lx.toks {
        if t.test || t.kind == TokKind::Str {
            continue;
        }
        eat(&t.text);
    }
    format!("{h:016x}")
}

/// Replace the `version` and `layout` lines of an existing manifest,
/// preserving everything else byte-for-byte.
pub fn rewrite_lock(text: &str, version: u32, layout: &str) -> String {
    let mut out = String::new();
    for raw in text.lines() {
        let t = raw.trim_start();
        if t.starts_with("version ") || t == "version" {
            out.push_str(&format!("version {version}\n"));
        } else if t.starts_with("layout ") || t == "layout" {
            out.push_str(&format!("layout {layout}\n"));
        } else {
            out.push_str(raw);
            out.push('\n');
        }
    }
    out
}

fn finding(path: &str, line: u32, msg: String) -> Finding {
    Finding { path: path.into(), line, rule: "wire", msg }
}

/// Full rule-3 check. `lock_text` None = PROTOCOL.lock missing.
pub fn check(
    wire: &SourceFile,
    wire_lx: &Lexed,
    _framing: &SourceFile,
    framing_lx: &Lexed,
    lock_text: Option<&str>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(text) = lock_text else {
        out.push(finding(
            LOCK_PATH,
            0,
            "PROTOCOL.lock missing — regenerate with `cargo run --bin alps_lint -- --write-protocol-lock`".into(),
        ));
        return out;
    };
    let lock = match parse_lock(text) {
        Ok(l) => l,
        Err(e) => {
            out.push(finding(LOCK_PATH, 0, format!("unparseable manifest: {e}")));
            return out;
        }
    };

    // tags: source <-> manifest, both directions, values included
    let src_tags = source_tags(wire_lx);
    for (name, value, line) in &src_tags {
        match lock.rows.iter().find(|r| &r.name == name) {
            None => out.push(finding(
                &wire.path,
                *line,
                format!("tag::{name} has no PROTOCOL.lock row — add one with its encoder, decoder and truncation labels"),
            )),
            Some(r) if r.value != *value => out.push(finding(
                LOCK_PATH,
                r.line,
                format!("tag {name} is {value} in wire.rs but {} in the manifest", r.value),
            )),
            _ => {}
        }
    }
    for r in &lock.rows {
        if !src_tags.iter().any(|(n, _, _)| n == &r.name) {
            out.push(finding(
                LOCK_PATH,
                r.line,
                format!("stale row: tag {} no longer exists in pruning/wire.rs", r.name),
            ));
        }
    }

    // codec symbols must exist as non-test fns (with their type if pathed)
    let fns: Vec<&str> = wire_lx
        .toks
        .windows(2)
        .filter(|w| {
            !w[0].test
                && w[0].kind == TokKind::Ident
                && w[0].text == "fn"
                && w[1].kind == TokKind::Ident
        })
        .map(|w| w[1].text.as_str())
        .collect();
    let idents: Vec<&str> = wire_lx
        .toks
        .iter()
        .filter(|t| !t.test && t.kind == TokKind::Ident)
        .map(|t| t.text.as_str())
        .collect();
    let require_symbol = |sym: &str, row: &TagRow, role: &str, out: &mut Vec<Finding>| {
        let (ty, func) = match sym.rsplit_once("::") {
            Some((ty, f)) => (Some(ty), f),
            None => (None, sym),
        };
        let ty_ok = match ty {
            Some(t) => idents.contains(&t),
            None => true,
        };
        let ok = fns.contains(&func) && ty_ok;
        if !ok {
            out.push(finding(
                LOCK_PATH,
                row.line,
                format!(
                    "tag {} {role} '{sym}' not found as a non-test fn in pruning/wire.rs",
                    row.name
                ),
            ));
        }
    };
    for r in &lock.rows {
        require_symbol(&r.encode, r, "encoder", &mut out);
        require_symbol(&r.decode, r, "decoder", &mut out);
    }

    // the per-byte truncation test must exist and exercise every label
    match test_fn_strings(wire_lx, &lock.truncation_test) {
        None => out.push(finding(
            &wire.path,
            0,
            format!(
                "truncation test '{}' (named in PROTOCOL.lock) not found in pruning/wire.rs test code",
                lock.truncation_test
            ),
        )),
        Some((strs, idents_in_test)) => {
            if !idents_in_test.iter().any(|s| s == "cut") {
                out.push(finding(
                    &wire.path,
                    0,
                    format!(
                        "truncation test '{}' no longer loops per byte (no `cut` variable)",
                        lock.truncation_test
                    ),
                ));
            }
            for r in &lock.rows {
                for label in &r.truncation {
                    if !strs.iter().any(|s| s == label) {
                        out.push(finding(
                            &wire.path,
                            0,
                            format!(
                                "truncation test '{}' lost the '{}' payload labelled for tag {}",
                                lock.truncation_test, label, r.name
                            ),
                        ));
                    }
                }
            }
        }
    }

    // FRAME_VERSION must match the manifest
    match frame_version(framing_lx) {
        None => out.push(finding("net/framing.rs", 0, "FRAME_VERSION const not found".into())),
        Some(v) if v != lock.version => out.push(finding(
            LOCK_PATH,
            0,
            format!(
                "manifest version {} != net::framing::FRAME_VERSION {v} — refresh with --write-protocol-lock",
                lock.version
            ),
        )),
        _ => {}
    }

    // layout fingerprint ('pending' = bootstrap, accepted with a notice)
    let computed = layout_hash(wire_lx);
    if lock.layout != "pending" && lock.layout != computed {
        out.push(finding(
            LOCK_PATH,
            0,
            format!(
                "codec layout drifted (manifest {}, source {computed}) — bump FRAME_VERSION in net/framing.rs, then `cargo run --bin alps_lint -- --write-protocol-lock`",
                lock.layout
            ),
        ));
    }
    out
}

/// Locate a `#[cfg(test)]`-marked fn by name and return (string
/// literals, identifiers) of its body.
fn test_fn_strings(lx: &Lexed, name: &str) -> Option<(Vec<String>, Vec<String>)> {
    let toks = &lx.toks;
    let pos = toks.windows(2).position(|w| {
        w[0].test && w[0].kind == TokKind::Ident && w[0].text == "fn" && w[1].text == name
    })?;
    // body = first brace-matched block after the name
    let mut i = pos + 2;
    while i < toks.len() && !(toks[i].kind == TokKind::Punct && toks[i].text == "{") {
        i += 1;
    }
    let mut depth = 0usize;
    let mut strs = Vec::new();
    let mut idents = Vec::new();
    for t in toks.iter().skip(i) {
        match t.kind {
            TokKind::Punct if t.text == "{" => depth += 1,
            TokKind::Punct if t.text == "}" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            TokKind::Str => strs.push(t.text.clone()),
            TokKind::Ident => idents.push(t.text.clone()),
            _ => {}
        }
    }
    Some((strs, idents))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    const GOOD_WIRE: &str = r#"
pub mod tag {
    pub const SOLVE: u8 = 1;
    pub const ERROR: u8 = 3;
}
pub fn encode_solve(x: u8) -> Vec<u8> { vec![x] }
pub struct SolveRequest;
impl SolveRequest {
    pub fn decode(b: &[u8]) -> Result<Self, ()> { let _ = b; Ok(SolveRequest) }
}
pub fn encode_error(j: u64) -> Vec<u8> { vec![j as u8] }
pub fn decode_error(b: &[u8]) -> Result<u64, ()> { let _ = b; Ok(0) }
#[cfg(test)]
mod tests {
    #[test]
    fn every_truncation_of_every_payload_errors() {
        for (label, buf) in [("solve", &[1u8][..]), ("error", &[3u8][..])] {
            for cut in 0..buf.len() {
                let _ = (label, cut);
            }
        }
    }
}
"#;

    const FRAMING: &str = "pub const FRAME_VERSION: u8 = 2;\n";

    const GOOD_LOCK: &str = "\
# test manifest
version 2
truncation-test every_truncation_of_every_payload_errors
tag SOLVE 1 encode=encode_solve decode=SolveRequest::decode truncation=solve
tag ERROR 3 encode=encode_error decode=decode_error truncation=error
layout pending
";

    fn run(wire_src: &str, lock: Option<&str>) -> Vec<Finding> {
        let wire = SourceFile { path: "pruning/wire.rs".into(), text: wire_src.into() };
        let framing = SourceFile { path: "net/framing.rs".into(), text: FRAMING.into() };
        let wlx = lex(wire_src);
        let flx = lex(FRAMING);
        check(&wire, &wlx, &framing, &flx, lock)
    }

    #[test]
    fn conformant_tree_passes() {
        let out = run(GOOD_WIRE, Some(GOOD_LOCK));
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn missing_manifest_fails() {
        let out = run(GOOD_WIRE, None);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("PROTOCOL.lock missing"));
    }

    #[test]
    fn new_tag_without_row_fails() {
        let src = GOOD_WIRE.replace(
            "pub const ERROR: u8 = 3;",
            "pub const ERROR: u8 = 3;\n    pub const PING: u8 = 9;",
        );
        let out = run(&src, Some(GOOD_LOCK));
        assert!(
            out.iter().any(|f| f.msg.contains("tag::PING has no PROTOCOL.lock row")),
            "{out:?}"
        );
    }

    #[test]
    fn deleted_truncation_payload_fails() {
        let src = GOOD_WIRE.replace("(\"error\", &[3u8][..])", "");
        let out = run(&src, Some(GOOD_LOCK));
        assert!(
            out.iter().any(|f| f.msg.contains("lost the 'error' payload")),
            "{out:?}"
        );
    }

    #[test]
    fn renamed_codec_symbol_fails() {
        let src = GOOD_WIRE.replace("pub fn decode_error", "pub fn decode_err2");
        let out = run(&src, Some(GOOD_LOCK));
        assert!(out.iter().any(|f| f.msg.contains("decoder 'decode_error' not found")), "{out:?}");
    }

    #[test]
    fn version_mismatch_and_layout_drift_fail() {
        let lock = GOOD_LOCK.replace("version 2", "version 1");
        let out = run(GOOD_WIRE, Some(&lock));
        assert!(out.iter().any(|f| f.msg.contains("FRAME_VERSION")), "{out:?}");

        let wlx = lex(GOOD_WIRE);
        let real = layout_hash(&wlx);
        let pinned = GOOD_LOCK.replace("layout pending", &format!("layout {real}"));
        assert!(run(GOOD_WIRE, Some(&pinned)).is_empty());
        // structural change (new fn) drifts the fingerprint...
        let drifted =
            GOOD_WIRE.replace("pub fn encode_error(j: u64)", "pub fn encode_error(j: u32)");
        let out2 = run(&drifted, Some(&pinned));
        assert!(out2.iter().any(|f| f.msg.contains("layout drifted")), "{out2:?}");
        // ...but string-literal content is not layout
        assert_eq!(
            layout_hash(&lex("fn e() { err(\"old message\") }")),
            layout_hash(&lex("fn e() { err(\"new message\") }")),
        );
    }

    #[test]
    fn stale_row_and_value_mismatch_fail() {
        let lock = format!("{GOOD_LOCK}tag GONE 7 encode=encode_error decode=decode_error truncation=error\n");
        let out = run(GOOD_WIRE, Some(&lock));
        assert!(out.iter().any(|f| f.msg.contains("stale row: tag GONE")), "{out:?}");

        let lock2 = GOOD_LOCK.replace("tag ERROR 3", "tag ERROR 4");
        let out2 = run(GOOD_WIRE, Some(&lock2));
        assert!(out2.iter().any(|f| f.msg.contains("is 3 in wire.rs but 4")), "{out2:?}");
    }

    #[test]
    fn rewrite_preserves_rows() {
        let new = rewrite_lock(GOOD_LOCK, 3, "deadbeefdeadbeef");
        assert!(new.contains("version 3\n"));
        assert!(new.contains("layout deadbeefdeadbeef\n"));
        assert!(new.contains("tag SOLVE 1"));
        assert!(new.contains("# test manifest"));
    }
}
