//! Token-level Rust lexer for `alps-lint` — string/comment/lifetime
//! aware, no external parser.
//!
//! This is deliberately **not** a full Rust grammar: the lint rules only
//! need a faithful token stream (so `unwrap` inside a string literal or
//! a comment is never mistaken for a call) plus two annotations computed
//! here because they need raw source access:
//!
//! * `lint:allow(<kind>) <reason>` markers collected from comments
//!   (comments are otherwise dropped from the token stream), and
//! * a per-token `test` flag marking everything under a `#[cfg(test)]`
//!   attribute (the attribute's item — brace-matched block or up to the
//!   terminating `;`) so rules can skip test code.
//!
//! Handled syntax: line + nested block comments, string/char/byte
//! literals with escapes, raw (byte) strings with arbitrary `#` fences,
//! lifetimes vs char literals, float literals. Unhandled corner cases
//! (e.g. `'static` inside macro fragments) degrade to extra `Punct`
//! tokens, which no rule matches on — safe in both directions.

/// Token classes the rules dispatch on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String literal; `text` holds the *content* (no quotes/fences).
    Str,
    Num,
    /// Lifetime (`'a`) — kept distinct so `'a` never looks like an ident.
    Life,
    /// Single punctuation character.
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// True when the token sits inside a `#[cfg(test)]` item.
    pub test: bool,
}

/// A `lint:allow(<kind>) <reason>` marker found in a comment.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: u32,
    pub kind: String,
    pub reason: String,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
}

pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let at = |i: usize| if i < n { b[i] } else { '\0' };
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (covers `///` and `//!` doc comments too)
        if c == '/' && at(i + 1) == '/' {
            let mut text = String::new();
            i += 2;
            while i < n && b[i] != '\n' {
                text.push(b[i]);
                i += 1;
            }
            scan_allow(&text, line, &mut out.allows);
            continue;
        }
        // nested block comment
        if c == '/' && at(i + 1) == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut text = String::new();
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && at(i + 1) == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && at(i + 1) == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    text.push(b[i]);
                    i += 1;
                }
            }
            scan_allow(&text, start_line, &mut out.allows);
            continue;
        }
        // raw strings / byte strings / byte chars: r" r#" b" br" b'
        if c == 'r' || c == 'b' {
            let is_raw = c == 'r' || at(i + 1) == 'r';
            let j = if c == 'b' && at(i + 1) == 'r' { i + 2 } else { i + 1 };
            if is_raw {
                let mut hashes = 0usize;
                let mut k = j;
                while at(k) == '#' {
                    hashes += 1;
                    k += 1;
                }
                if at(k) == '"' {
                    k += 1;
                    let start_line = line;
                    let mut text = String::new();
                    'raw: while k < n {
                        if b[k] == '"' {
                            let mut m = 0usize;
                            while m < hashes && at(k + 1 + m) == '#' {
                                m += 1;
                            }
                            if m == hashes {
                                k += 1 + hashes;
                                break 'raw;
                            }
                        }
                        if b[k] == '\n' {
                            line += 1;
                        }
                        text.push(b[k]);
                        k += 1;
                    }
                    out.toks.push(Tok { kind: TokKind::Str, text, line: start_line, test: false });
                    i = k;
                    continue;
                }
            }
            if c == 'b' && at(i + 1) == '"' {
                let (text, j2, nl) = read_quoted(&b, i + 2, '"');
                out.toks.push(Tok { kind: TokKind::Str, text, line, test: false });
                line += nl;
                i = j2;
                continue;
            }
            if c == 'b' && at(i + 1) == '\'' {
                let (_, j2, nl) = read_quoted(&b, i + 2, '\'');
                line += nl;
                i = j2;
                continue;
            }
            // fall through: ordinary identifier starting with r/b
        }
        if c == '"' {
            let (text, j2, nl) = read_quoted(&b, i + 1, '"');
            out.toks.push(Tok { kind: TokKind::Str, text, line, test: false });
            line += nl;
            i = j2;
            continue;
        }
        if c == '\'' {
            // lifetime iff followed by ident chars and no closing quote
            // right after a single char (`'a'` is a char, `'a` a lifetime)
            if (at(i + 1).is_alphabetic() || at(i + 1) == '_') && at(i + 2) != '\'' {
                let mut j = i + 1;
                let mut text = String::new();
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    text.push(b[j]);
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Life, text, line, test: false });
                i = j;
                continue;
            }
            let (_, j2, nl) = read_quoted(&b, i + 1, '\'');
            line += nl;
            i = j2;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            let mut text = String::new();
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                text.push(b[j]);
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text, line, test: false });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            let mut text = String::new();
            let mut seen_dot = false;
            while j < n {
                let d = b[j];
                if d.is_alphanumeric() || d == '_' {
                    text.push(d);
                    j += 1;
                } else if d == '.' && !seen_dot && at(j + 1).is_ascii_digit() {
                    seen_dot = true;
                    text.push(d);
                    j += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok { kind: TokKind::Num, text, line, test: false });
            i = j;
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line, test: false });
        i += 1;
    }
    mark_tests(&mut out.toks);
    out
}

/// Read a quoted literal body starting *after* the opening quote; returns
/// (content, index past closing quote, newlines consumed).
fn read_quoted(b: &[char], mut i: usize, close: char) -> (String, usize, u32) {
    let n = b.len();
    let mut text = String::new();
    let mut nl = 0u32;
    while i < n {
        let c = b[i];
        if c == '\\' && i + 1 < n {
            if b[i + 1] == '\n' {
                nl += 1;
            }
            text.push(c);
            text.push(b[i + 1]);
            i += 2;
            continue;
        }
        if c == close {
            i += 1;
            break;
        }
        if c == '\n' {
            nl += 1;
        }
        text.push(c);
        i += 1;
    }
    (text, i, nl)
}

/// Collect `lint:allow(kind) reason` from one comment's text.
fn scan_allow(comment: &str, line: u32, allows: &mut Vec<Allow>) {
    let Some(pos) = comment.find("lint:allow(") else { return };
    let rest = &comment[pos + "lint:allow(".len()..];
    let Some(close) = rest.find(')') else {
        allows.push(Allow { line, kind: String::new(), reason: String::new() });
        return;
    };
    let kind = rest[..close].trim().to_string();
    let reason = rest[close + 1..].trim().to_string();
    allows.push(Allow { line, kind, reason });
}

/// Mark every token under a `#[cfg(test)]` attribute as test code. The
/// attribute governs the next item: everything through the matching
/// close of the first `{` opened after it, or through the first `;`
/// before any brace opens (e.g. `#[cfg(test)] use x;`).
fn mark_tests(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if !is_cfg_test_at(toks, i) {
            i += 1;
            continue;
        }
        let mut j = i + 7; // past `# [ cfg ( test ) ]`
        let mut depth = 0usize;
        let mut opened = false;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => {
                        depth += 1;
                        opened = true;
                    }
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if opened && depth == 0 {
                            break;
                        }
                    }
                    ";" if !opened => break,
                    _ => {}
                }
            }
            j += 1;
        }
        let end = j.min(toks.len().saturating_sub(1));
        for t in toks.iter_mut().take(end + 1).skip(i) {
            t.test = true;
        }
        i = end + 1;
    }
}

fn is_cfg_test_at(toks: &[Tok], i: usize) -> bool {
    let want: [(&str, TokKind); 7] = [
        ("#", TokKind::Punct),
        ("[", TokKind::Punct),
        ("cfg", TokKind::Ident),
        ("(", TokKind::Punct),
        ("test", TokKind::Ident),
        (")", TokKind::Punct),
        ("]", TokKind::Punct),
    ];
    if i + want.len() > toks.len() {
        return false;
    }
    want.iter().enumerate().all(|(k, (text, kind))| {
        let t = &toks[i + k];
        t.kind == *kind && t.text == *text
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<(TokKind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let lx = lex("let s = \"x.unwrap()\"; // also .unwrap()\n/* and .unwrap() */ y");
        assert!(!lx.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "unwrap"));
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Str && t.text == "x.unwrap()"));
    }

    #[test]
    fn raw_strings_with_fences() {
        let got = texts("r#\"a \"quote\" b\"# z");
        assert_eq!(got[0], (TokKind::Str, "a \"quote\" b".into()));
        assert_eq!(got[1], (TokKind::Ident, "z".into()));
    }

    #[test]
    fn lifetime_vs_char() {
        let got = texts("&'a str; let c = 'x'; let nl = '\\n';");
        assert!(got.contains(&(TokKind::Life, "a".into())));
        assert!(!got.iter().any(|(k, t)| *k == TokKind::Ident && t == "x"));
    }

    #[test]
    fn lines_track_through_multiline_constructs() {
        let lx = lex("a\n/* c\nc */\n\"s\ns\"\nb");
        let b = lx.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 6);
    }

    #[test]
    fn nested_block_comments() {
        let got = texts("/* outer /* inner */ still */ x");
        assert_eq!(got, vec![(TokKind::Ident, "x".into())]);
    }

    #[test]
    fn cfg_test_marks_the_next_item_only() {
        let lx = lex("fn live() { a.unwrap(); }\n#[cfg(test)]\nmod tests { fn t() { b.unwrap(); } }\nfn live2() { c() }");
        let unwraps: Vec<bool> =
            lx.toks.iter().filter(|t| t.text == "unwrap").map(|t| t.test).collect();
        assert_eq!(unwraps, vec![false, true]);
        let live2 = lx.toks.iter().find(|t| t.text == "live2").unwrap();
        assert!(!live2.test);
    }

    #[test]
    fn cfg_not_test_is_not_marked() {
        let lx = lex("#[cfg(not(test))]\nfn f() { a.unwrap(); }");
        assert!(lx.toks.iter().all(|t| !t.test));
    }

    #[test]
    fn allow_markers_carry_kind_and_reason() {
        let lx = lex("// lint:allow(panic) poison here means a prior abort\nx.unwrap();");
        assert_eq!(lx.allows.len(), 1);
        assert_eq!(lx.allows[0].kind, "panic");
        assert_eq!(lx.allows[0].line, 1);
        assert!(lx.allows[0].reason.starts_with("poison"));
    }

    #[test]
    fn allow_without_reason_is_kept_for_reporting() {
        let lx = lex("// lint:allow(lock)\n");
        assert_eq!(lx.allows[0].reason, "");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let got = texts("b\"AF\" b'x' ident");
        assert_eq!(got[0], (TokKind::Str, "AF".into()));
        assert_eq!(got.last().unwrap(), &(TokKind::Ident, "ident".into()));
    }
}
