//! Rule 4 — metric-naming conformance.
//!
//! Every metric name literal (`"alps_..."` in non-test code) must carry
//! the `alps_<subsystem>_` prefix assigned to the module registering it,
//! and must appear as a row in the naming table of the [`crate::obs`]
//! module doc (`//! | `alps_...` | kind | module |`). The check runs in
//! both directions: an unlisted registration fails, and a table row
//! whose metric no longer exists in code fails as stale — renaming a
//! metric without updating the doc exits non-zero either way.

use super::lexer::{Lexed, TokKind};
use super::{Finding, SourceFile};

/// Module prefix ownership. `obs/` may mention any `alps_` name (it is
/// the registry and the doc table). Modules not listed here must not
/// register metrics until given a row.
const SUBSYSTEMS: &[(&str, &str)] = &[
    ("net/", "alps_net_"),
    ("serve/", "alps_serve_"),
    ("coordinator/", "alps_coord_"),
    ("pruning/", "alps_prune_"),
    ("obs/", "alps_"),
];

/// A string literal counts as a metric name when it looks like one:
/// `alps_` + lowercase/digit/underscore body, not a glob/family stub.
fn is_metric_literal(s: &str) -> bool {
    s.len() > "alps_".len()
        && s.starts_with("alps_")
        && !s.ends_with('_')
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Parse the obs module-doc naming table rows: lines shaped
/// ``//! | `alps_...` | ... |``. Returns (name, 1-based line).
pub fn doc_table(obs_mod_src: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (i, raw) in obs_mod_src.lines().enumerate() {
        let line = raw.trim_start();
        let Some(rest) = line.strip_prefix("//!") else { continue };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("| `") else { continue };
        let Some(end) = rest.find('`') else { continue };
        let name = &rest[..end];
        if is_metric_literal(name) {
            out.push((name.to_string(), i as u32 + 1));
        }
    }
    out
}

pub fn check(
    files: &[SourceFile],
    lexed: &[(usize, Lexed)],
    obs_mod: Option<&SourceFile>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let table = match obs_mod {
        Some(f) => doc_table(&f.text),
        None => {
            out.push(Finding {
                path: "obs/mod.rs".into(),
                line: 0,
                rule: "metric",
                msg: "obs/mod.rs missing — no metric naming table to check against".into(),
            });
            return out;
        }
    };
    let mut seen: Vec<&str> = Vec::new();
    for (i, lx) in lexed {
        let file = &files[*i];
        let subsystem = SUBSYSTEMS.iter().find(|(dir, _)| file.path.starts_with(dir));
        for t in &lx.toks {
            if t.test || t.kind != TokKind::Str || !is_metric_literal(&t.text) {
                continue;
            }
            let name = t.text.as_str();
            match subsystem {
                None => {
                    out.push(Finding {
                        path: file.path.clone(),
                        line: t.line,
                        rule: "metric",
                        msg: format!(
                            "metric literal '{name}' in a module with no assigned subsystem prefix — extend lint::metrics::SUBSYSTEMS deliberately"
                        ),
                    });
                    continue;
                }
                Some((_, prefix)) if !name.starts_with(prefix) => out.push(Finding {
                    path: file.path.clone(),
                    line: t.line,
                    rule: "metric",
                    msg: format!("metric '{name}' must use the {prefix}* prefix for this module"),
                }),
                _ => {}
            }
            if !table.iter().any(|(n, _)| n == name) {
                out.push(Finding {
                    path: file.path.clone(),
                    line: t.line,
                    rule: "metric",
                    msg: format!(
                        "metric '{name}' is not in the obs/mod.rs naming table — add a `| \\`{name}\\` | kind | module |` row"
                    ),
                });
            }
            seen.push(name);
        }
    }
    for (name, line) in &table {
        if !seen.iter().any(|s| s == name) {
            out.push(Finding {
                path: "obs/mod.rs".into(),
                line: *line,
                rule: "metric",
                msg: format!("stale naming-table row: '{name}' is registered nowhere in live code"),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let srcs: Vec<SourceFile> = files
            .iter()
            .map(|(p, s)| SourceFile { path: (*p).into(), text: (*s).into() })
            .collect();
        let lexed: Vec<(usize, Lexed)> =
            srcs.iter().enumerate().map(|(i, f)| (i, lex(&f.text))).collect();
        let obs = srcs.iter().find(|f| f.path == "obs/mod.rs").cloned();
        check(&srcs, &lexed, obs.as_ref())
    }

    const OBS_MOD: &str = "\
//! obs.
//!
//! | metric | kind | registered in |
//! |---|---|---|
//! | `alps_net_frames_total` | counter | `net::framing` |
//! | `alps_serve_tokens_total` | counter | `serve::metrics` |
";

    #[test]
    fn table_parse_skips_globs_and_prose() {
        let rows = doc_table("//! | `alps_net_frames_total` | c | m |\n//! | `alps_net_` | family | m |\n//! `alps_inline_mention_total` in prose\n");
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, "alps_net_frames_total");
    }

    #[test]
    fn conformant_metrics_pass() {
        let out = run(&[
            ("obs/mod.rs", OBS_MOD),
            ("net/framing.rs", "fn m() { r.counter(\"alps_net_frames_total\", \"h\", &[]); }"),
            ("serve/metrics.rs", "fn m() { r.counter(\"alps_serve_tokens_total\", \"h\", &[]); }"),
        ]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn misnamed_metric_fails() {
        let out = run(&[
            ("obs/mod.rs", OBS_MOD),
            ("serve/metrics.rs", "fn m() { r.counter(\"alps_net_frames_total\", \"h\", &[]); }"),
        ]);
        assert!(
            out.iter().any(|f| f.msg.contains("must use the alps_serve_* prefix")),
            "{out:?}"
        );
    }

    #[test]
    fn unlisted_metric_and_stale_row_fail() {
        let out = run(&[
            ("obs/mod.rs", OBS_MOD),
            ("net/framing.rs", "fn m() { r.counter(\"alps_net_frames_total\", \"h\", &[]); }"),
            ("net/server.rs", "fn m() { r.counter(\"alps_net_brand_new_total\", \"h\", &[]); }"),
        ]);
        assert!(
            out.iter().any(|f| f.msg.contains("not in the obs/mod.rs naming table")),
            "{out:?}"
        );
        // alps_serve_tokens_total is in the table but never registered
        assert!(out.iter().any(|f| f.msg.contains("stale naming-table row")), "{out:?}");
    }

    #[test]
    fn unmapped_module_and_test_code() {
        let out = run(&[
            ("obs/mod.rs", OBS_MOD),
            ("net/framing.rs", "fn m() { r.counter(\"alps_net_frames_total\", \"h\", &[]); }"),
            ("serve/metrics.rs", "fn m() { r.counter(\"alps_serve_tokens_total\", \"h\", &[]); }"),
            ("linalg/mod.rs", "fn m() { r.counter(\"alps_linalg_mm_total\", \"h\", &[]); }"),
            (
                "pruning/session.rs",
                "#[cfg(test)]\nmod tests { fn t() { r.counter(\"alps_session_fixture\", \"h\", &[]); } }",
            ),
        ]);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("no assigned subsystem prefix"));
        assert_eq!(out[0].path, "linalg/mod.rs");
    }
}
