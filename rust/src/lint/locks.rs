//! Rule 2 — lock discipline.
//!
//! Two checks over watched files:
//!
//! * **Raw locks**: any `.lock()` method call must be replaced by the
//!   poison-tolerant [`crate::net::lock`] helper (a poisoned server
//!   mutex must degrade, not cascade the panic). The helper itself
//!   carries the one `lint:allow(lock)` in the tree.
//! * **Acquisition order**: a per-function scan tracks which `lock(..)`
//!   guards are held at each later `lock(..)` call, accumulating a
//!   global ordered graph keyed by the mutex's field name (the last
//!   path identifier of the argument — `&shared.batcher` → `batcher`).
//!   A cycle means two call paths can acquire the same pair of locks in
//!   opposite order — a potential deadlock — and fails the gate.
//!
//! Guard lifetimes follow Rust's drop rules, conservatively: `let g =
//! lock(..);` holds to end of scope or `drop(g)`; `match`/`if let`
//! scrutinees and other temporaries hold to the end of the enclosing
//! statement; a plain `if`/`while` condition releases at the body brace.
//! The scan is intra-function (closures are analyzed at their
//! definition site); cross-function nesting is out of scope and covered
//! dynamically by the nightly TSan job.

use std::collections::BTreeMap;

use super::lexer::{Lexed, Tok, TokKind};
use super::{Finding, SourceFile};

/// Flag raw `.lock()` method calls (rule tag `lock`, suppressible).
pub fn scan_raw_locks(file: &SourceFile, lx: &Lexed, out: &mut Vec<Finding>) {
    let toks = &lx.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.test || t.kind != TokKind::Ident || t.text != "lock" {
            continue;
        }
        let prev_dot = i > 0 && toks[i - 1].kind == TokKind::Punct && toks[i - 1].text == ".";
        let next_paren =
            toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Punct && n.text == "(");
        if prev_dot && next_paren {
            out.push(Finding {
                path: file.path.clone(),
                line: t.line,
                rule: "lock",
                msg: "raw .lock() — use net::lock (poison-tolerant); else lint:allow(lock)".into(),
            });
        }
    }
}

/// Global lock-order graph: directed edge `a -> b` = "somewhere, `b` is
/// acquired while `a` is held", with one witness site per edge.
#[derive(Default)]
pub struct LockGraph {
    edges: BTreeMap<(String, String), (String, u32)>,
}

#[derive(Clone, Copy, PartialEq)]
enum Release {
    /// `let g = lock(..);` — end of scope or `drop(g)`.
    Scope,
    /// Temporary — end of the enclosing statement.
    Stmt,
    /// Plain `if`/`while` condition — the body `{`.
    Body,
}

struct Guard {
    name: String,
    var: Option<String>,
    release: Release,
    depth_at: usize,
}

impl LockGraph {
    fn add_edge(&mut self, from: &str, to: &str, path: &str, line: u32) {
        self.edges
            .entry((from.into(), to.into()))
            .or_insert_with(|| (path.into(), line));
    }

    /// DFS for back edges; each one is a potential deadlock cycle.
    pub fn check_cycles(&self) -> Vec<Finding> {
        let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
        for (a, b) in self.edges.keys() {
            adj.entry(a).or_default().push(b);
        }
        let mut color: BTreeMap<&str, u8> = BTreeMap::new(); // 0 white 1 gray 2 black
        let mut stack: Vec<&str> = Vec::new();
        let mut out = Vec::new();
        let roots: Vec<&str> = adj.keys().copied().collect();
        for root in roots {
            self.dfs(root, &adj, &mut color, &mut stack, &mut out);
        }
        out
    }

    fn dfs<'a>(
        &'a self,
        node: &'a str,
        adj: &BTreeMap<&'a str, Vec<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
        out: &mut Vec<Finding>,
    ) {
        match color.get(node) {
            Some(2) => return,
            Some(1) => return, // handled by caller via back-edge check
            _ => {}
        }
        color.insert(node, 1);
        stack.push(node);
        for &next in adj.get(node).into_iter().flatten() {
            if color.get(next) == Some(&1) {
                // back edge: cycle from `next` around to `node -> next`
                let pos = stack.iter().position(|&s| s == next).unwrap_or(0);
                let mut cycle: Vec<&str> = stack[pos..].to_vec();
                cycle.push(next);
                let (path, line) = self
                    .edges
                    .get(&(node.to_string(), next.to_string()))
                    .cloned()
                    .unwrap_or_default();
                out.push(Finding {
                    path,
                    line,
                    rule: "lock-order",
                    msg: format!(
                        "lock acquisition cycle {} — two paths can deadlock; acquire in one global order",
                        cycle.join(" -> ")
                    ),
                });
            } else {
                self.dfs(next, adj, color, stack, out);
            }
        }
        stack.pop();
        color.insert(node, 2);
    }
}

/// Scan one file's non-test functions, adding held-lock edges to `graph`.
pub fn scan_order(file: &SourceFile, lx: &Lexed, graph: &mut LockGraph) {
    let toks = &lx.toks;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.test || t.kind != TokKind::Ident || t.text != "fn" {
            i += 1;
            continue;
        }
        // find the body `{` at paren depth 0, or `;` (bodyless decl)
        let mut j = i + 1;
        let mut paren = 0isize;
        let mut body = None;
        while j < toks.len() {
            let u = &toks[j];
            if u.kind == TokKind::Punct {
                match u.text.as_str() {
                    "(" => paren += 1,
                    ")" => paren -= 1,
                    "{" if paren == 0 => {
                        body = Some(j);
                        break;
                    }
                    ";" if paren == 0 => break,
                    _ => {}
                }
            }
            j += 1;
        }
        let Some(start) = body else {
            i = j + 1;
            continue;
        };
        let end = match_brace(toks, start);
        scan_fn_body(file, toks, start, end, graph);
        i = end + 1;
    }
}

fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len() - 1
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn scan_fn_body(file: &SourceFile, toks: &[Tok], start: usize, end: usize, graph: &mut LockGraph) {
    let mut held: Vec<Guard> = Vec::new();
    let mut depth = 1usize; // inside the body `{`
    let mut i = start + 1;
    while i < end {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => {
                    held.retain(|g| g.release != Release::Body);
                    depth += 1;
                }
                "}" => {
                    depth = depth.saturating_sub(1);
                    held.retain(|g| g.depth_at <= depth);
                }
                ";" => held.retain(|g| !(g.release == Release::Stmt && g.depth_at == depth)),
                _ => {}
            }
            i += 1;
            continue;
        }
        // drop(var) releases a named guard
        if t.kind == TokKind::Ident
            && t.text == "drop"
            && i + 3 < end
            && is_punct(&toks[i + 1], "(")
            && toks[i + 2].kind == TokKind::Ident
            && is_punct(&toks[i + 3], ")")
        {
            let var = &toks[i + 2].text;
            held.retain(|g| g.var.as_deref() != Some(var.as_str()));
            i += 4;
            continue;
        }
        // free call to the lock helper (`lock(` / `net::lock(`), not a
        // method (`.lock(`) and not the helper's own definition (`fn lock`)
        if t.kind == TokKind::Ident && t.text == "lock" {
            let prev = &toks[i - 1];
            let free_call = !is_punct(prev, ".")
                && !(prev.kind == TokKind::Ident && prev.text == "fn")
                && toks.get(i + 1).is_some_and(|n| is_punct(n, "("));
            if free_call {
                let (name, close) = lock_arg_name(toks, i + 1, end);
                for g in &held {
                    graph.add_edge(&g.name, &name, &file.path, t.line);
                }
                let (release, var) = classify(toks, start, i, close);
                held.push(Guard { name, var, release, depth_at: depth });
                i += 1;
                continue;
            }
        }
        i += 1;
    }
}

/// Lock identity = last path identifier of the argument before any
/// indexing: `&self.conns[widx]` → `conns`, `&d.pending` → `pending`.
fn lock_arg_name(toks: &[Tok], open: usize, end: usize) -> (String, usize) {
    let mut depth = 0isize;
    let mut name = String::from("?");
    let mut k = open;
    while k < end {
        let t = &toks[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return (name, k);
                    }
                }
                "[" if depth == 1 => {
                    // skip the index expression, keep the container name
                    let mut b = 1isize;
                    k += 1;
                    while k < end && b > 0 {
                        if is_punct(&toks[k], "[") {
                            b += 1;
                        } else if is_punct(&toks[k], "]") {
                            b -= 1;
                        }
                        k += 1;
                    }
                    continue;
                }
                _ => {}
            }
        } else if t.kind == TokKind::Ident && t.text != "mut" {
            name = t.text.clone();
        }
        k += 1;
    }
    (name, end)
}

/// Decide when a freshly acquired guard is released, from the statement
/// context: backward scan to the statement start (`;`/`{`/`}` boundary).
fn classify(
    toks: &[Tok],
    body_start: usize,
    lock_idx: usize,
    close: usize,
) -> (Release, Option<String>) {
    let mut s = lock_idx;
    while s > body_start + 1 {
        let p = &toks[s - 1];
        if is_punct(p, ";") || is_punct(p, "{") || is_punct(p, "}") {
            break;
        }
        s -= 1;
    }
    let first = &toks[s];
    if first.kind == TokKind::Ident {
        match first.text.as_str() {
            "let" => {
                // `let [mut] var = <path::>lock(..);` binds a named guard
                let mut k = s + 1;
                if toks.get(k).is_some_and(|t| t.kind == TokKind::Ident && t.text == "mut") {
                    k += 1;
                }
                let var_ok = toks.get(k).map(|t| t.kind == TokKind::Ident).unwrap_or(false);
                let eq_ok = toks.get(k + 1).is_some_and(|t| is_punct(t, "="));
                let rhs_is_path = var_ok
                    && eq_ok
                    && toks[k + 2..=lock_idx]
                        .iter()
                        .all(|t| t.kind == TokKind::Ident || is_punct(t, ":"));
                let ends_stmt = toks.get(close + 1).is_some_and(|t| is_punct(t, ";"));
                if rhs_is_path && ends_stmt {
                    return (Release::Scope, Some(toks[k].text.clone()));
                }
                (Release::Stmt, None)
            }
            "if" | "while" => {
                let next_let =
                    toks.get(s + 1).is_some_and(|t| t.kind == TokKind::Ident && t.text == "let");
                if next_let {
                    (Release::Stmt, None)
                } else {
                    (Release::Body, None)
                }
            }
            _ => (Release::Stmt, None),
        }
    } else {
        (Release::Stmt, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn graph_of(srcs: &[(&str, &str)]) -> LockGraph {
        let mut g = LockGraph::default();
        for (path, src) in srcs {
            let f = SourceFile { path: (*path).into(), text: (*src).into() };
            let lx = lex(src);
            scan_order(&f, &lx, &mut g);
        }
        g
    }

    #[test]
    fn raw_lock_fires_and_helper_does_not() {
        let src = "fn f() {\n    let a = m.lock().unwrap();\n    let b = lock(&m2);\n}\n";
        let f = SourceFile { path: "net/fixture.rs".into(), text: src.into() };
        let lx = lex(src);
        let mut out = Vec::new();
        scan_raw_locks(&f, &lx, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn consistent_order_is_clean() {
        let g = graph_of(&[(
            "serve/a.rs",
            "fn x(s: &S) {\n    let mut b = lock(&s.batcher);\n    let mut r = lock(&s.replies);\n    b.go(); r.go();\n}\nfn y(s: &S) {\n    let mut b = lock(&s.batcher);\n    lock(&s.replies).insert(1);\n}\n",
        )]);
        assert!(g.check_cycles().is_empty());
        assert_eq!(g.edges.len(), 1); // batcher -> replies, witnessed twice
    }

    #[test]
    fn opposite_order_is_a_cycle() {
        let g = graph_of(&[
            (
                "serve/a.rs",
                "fn x(s: &S) {\n    let b = lock(&s.batcher);\n    let r = lock(&s.replies);\n}\n",
            ),
            (
                "coordinator/b.rs",
                "fn y(s: &S) {\n    let r = lock(&s.replies);\n    let b = lock(&s.batcher);\n}\n",
            ),
        ]);
        let out = g.check_cycles();
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("batcher") && out[0].msg.contains("replies"));
        assert_eq!(out[0].rule, "lock-order");
    }

    #[test]
    fn drop_and_scope_release_guards() {
        // b is dropped before r: no edge. s2's guard dies with its block.
        let g = graph_of(&[(
            "serve/a.rs",
            "fn x(s: &S) {\n    let b = lock(&s.batcher);\n    drop(b);\n    let r = lock(&s.replies);\n}\nfn y(s: &S) {\n    { let b = lock(&s.batcher); }\n    let r = lock(&s.replies);\n}\n",
        )]);
        assert!(g.edges.is_empty(), "{:?}", g.edges.keys().collect::<Vec<_>>());
    }

    #[test]
    fn plain_if_condition_releases_at_body() {
        let g = graph_of(&[(
            "net/a.rs",
            "fn x(s: &S) {\n    if lock(&s.pending).is_empty() {\n        let r = lock(&s.results);\n    }\n}\n",
        )]);
        assert!(g.edges.is_empty(), "{:?}", g.edges.keys().collect::<Vec<_>>());
    }

    #[test]
    fn match_scrutinee_holds_through_statement() {
        let g = graph_of(&[(
            "coordinator/a.rs",
            "fn x(s: &S) {\n    let v = match lock(&s.conns[i]).take() {\n        Some(c) => { let p = lock(&s.pending); 1 }\n        None => 0,\n    };\n    let after = lock(&s.results);\n}\n",
        )]);
        // conns held through the match (edge to pending) and released at
        // the statement's `;` — no edge to `results`
        assert!(g.edges.contains_key(&("conns".into(), "pending".into())));
        assert!(!g.edges.contains_key(&("conns".into(), "results".into())));
    }

    #[test]
    fn indexed_and_pathed_args_resolve_to_field_name() {
        let g = graph_of(&[(
            "net/a.rs",
            "fn x(s: &S, i: usize) {\n    let c = crate::net::lock(&s.conns[i]);\n    let p = lock(&s.pending);\n}\n",
        )]);
        assert!(
            g.edges.contains_key(&("conns".into(), "pending".into())),
            "{:?}",
            g.edges.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn temporaries_release_at_semicolon() {
        let g = graph_of(&[(
            "serve/a.rs",
            "fn x(s: &S) {\n    lock(&s.batcher).cancel(1);\n    let r = lock(&s.replies);\n}\n",
        )]);
        assert!(g.edges.is_empty(), "{:?}", g.edges.keys().collect::<Vec<_>>());
    }
}
