//! `alps-lint` — the repo's in-tree static-analysis gate.
//!
//! Run as `cargo run --bin alps_lint`; CI runs it as a blocking step
//! ahead of clippy. The tool walks `rust/src`, lexes every file with the
//! std-only token scanner in [`lexer`] (string/comment aware — no
//! external parser), and enforces four project invariants:
//!
//! 1. **Panic-freedom in server paths** ([`panics`]) — no `unwrap()` /
//!    `expect()` / `panic!` / `unreachable!` / `todo!` / `unimplemented!`
//!    in non-`#[cfg(test)]` code under the watched modules (`net/`,
//!    `serve/`, `coordinator/`, `obs/`, `sparse/`, and
//!    `pruning/{worker,wire,status,session}.rs`). A server that upholds
//!    bit-identical distributed runs must refuse a connection, not abort
//!    the process.
//! 2. **Lock discipline** ([`locks`]) — raw `.lock()` on a `Mutex` in
//!    watched modules must go through the poison-tolerant
//!    [`crate::net::lock`] helper, and a per-function held-lock scan
//!    builds a global lock-acquisition-order graph and fails on cycles
//!    (a static deadlock detector for the scheduler/batcher/dispatcher
//!    locks).
//! 3. **Wire-protocol conformance** ([`wire`]) — every `tag::` constant
//!    in `pruning/wire.rs` must have an encoder, a decoder, and a
//!    per-byte truncation test exercising its payload, all recorded in
//!    the committed `PROTOCOL.lock` manifest; a codec-layout fingerprint
//!    ties the manifest to `net::framing::FRAME_VERSION` so payload
//!    drift forces a deliberate version bump (regenerate with
//!    `cargo run --bin alps_lint -- --write-protocol-lock`).
//! 4. **Metric-naming conformance** ([`metrics`]) — every metric name
//!    literal must match `alps_<subsystem>_*` for the module it lives in
//!    and appear in the naming table in the [`crate::obs`] module doc
//!    (stale table rows fail too).
//!
//! ## Escape hatch
//!
//! A finding is suppressed by a comment on the same or the preceding
//! line: `// lint:allow(panic) <reason>` or `// lint:allow(lock)
//! <reason>`. The reason is mandatory, and each marker suppresses
//! **exactly one** finding — unused or unmatched markers are themselves
//! findings, so stale allows cannot accumulate.
//!
//! ## Known approximations
//!
//! The lock model is intentionally conservative: guards bound by `let`
//! are held to end of scope (or an explicit `drop(name)`), `match` /
//! `if let` scrutinee temporaries are held through the enclosing
//! statement, and plain `if`/`while` condition temporaries release at
//! the body brace — Rust's actual drop order, except that statement
//! over-approximation can extend a scrutinee guard to the end of its
//! block. Closures are scanned at their definition site as part of the
//! enclosing function. These over-approximations can only produce false
//! *cycles* (never missed ones among literal `lock(..)` call sites);
//! none occur in the current tree.

pub mod lexer;
pub mod locks;
pub mod metrics;
pub mod panics;
pub mod wire;

use lexer::{Allow, Lexed};

/// One source file handed to the rules: a path *relative to `rust/src`*
/// (always `/`-separated) plus its text.
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// A rule violation. `rule` is the short kind tag (`panic`, `lock`,
/// `lock-order`, `wire`, `metric`, `allow`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Server-path predicate: the modules where rules 1 and 2 apply.
pub fn is_server_path(path: &str) -> bool {
    path.starts_with("net/")
        || path.starts_with("serve/")
        || path.starts_with("coordinator/")
        || path.starts_with("obs/")
        || path.starts_with("sparse/")
        || matches!(
            path,
            "pruning/worker.rs" | "pruning/wire.rs" | "pruning/status.rs" | "pruning/session.rs"
        )
}

/// Which allow kinds exist, and which rule tags they suppress.
fn allow_suppresses(kind: &str, rule: &'static str) -> bool {
    matches!((kind, rule), ("panic", "panic") | ("lock", "lock"))
}

/// Apply `lint:allow` markers to raw findings from one file. Each marker
/// must carry a reason and suppresses exactly one finding on its own or
/// the following line; leftovers on either side surface as findings.
pub fn apply_allows(path: &str, raw: Vec<Finding>, allows: &[Allow]) -> Vec<Finding> {
    let mut used = vec![false; allows.len()];
    let mut out = Vec::new();
    for f in raw {
        let slot = allows.iter().enumerate().position(|(k, a)| {
            !used[k]
                && allow_suppresses(&a.kind, f.rule)
                && (a.line == f.line || a.line + 1 == f.line)
        });
        match slot {
            Some(k) if allows[k].reason.is_empty() => {
                used[k] = true;
                out.push(Finding {
                    path: path.into(),
                    line: allows[k].line,
                    rule: "allow",
                    msg: format!("lint:allow({}) requires a reason", allows[k].kind),
                });
            }
            Some(k) => used[k] = true,
            None => out.push(f),
        }
    }
    for (k, a) in allows.iter().enumerate() {
        if used[k] {
            continue;
        }
        if !matches!(a.kind.as_str(), "panic" | "lock") {
            out.push(Finding {
                path: path.into(),
                line: a.line,
                rule: "allow",
                msg: format!("unknown lint:allow kind '{}' (expected panic|lock)", a.kind),
            });
        } else {
            out.push(Finding {
                path: path.into(),
                line: a.line,
                rule: "allow",
                msg: format!(
                    "unused lint:allow({}) — nothing on this or the next line to suppress",
                    a.kind
                ),
            });
        }
    }
    out
}

/// Run every rule over an in-memory tree. `protocol_lock` is the text of
/// `PROTOCOL.lock` (None = missing, which is itself a finding). Returns
/// findings sorted by path/line.
pub fn check_sources(files: &[SourceFile], protocol_lock: Option<&str>) -> Vec<Finding> {
    let lexed: Vec<(usize, Lexed)> =
        files.iter().enumerate().map(|(i, f)| (i, lexer::lex(&f.text))).collect();
    let mut findings = Vec::new();

    let mut graph = locks::LockGraph::default();
    for (i, lx) in &lexed {
        let file = &files[*i];
        if is_server_path(&file.path) {
            let mut raw = Vec::new();
            panics::scan(file, lx, &mut raw);
            locks::scan_raw_locks(file, lx, &mut raw);
            findings.extend(apply_allows(&file.path, raw, &lx.allows));
            locks::scan_order(file, lx, &mut graph);
        }
        // `lint:allow` markers outside the watched modules are inert by
        // design — rules 1 and 2 only apply there, so only there can a
        // marker be matched (or flagged as unused)
    }
    findings.extend(graph.check_cycles());

    let wire_idx = files.iter().position(|f| f.path == "pruning/wire.rs");
    let framing_idx = files.iter().position(|f| f.path == "net/framing.rs");
    match (wire_idx, framing_idx) {
        (Some(w), Some(fr)) => {
            findings.extend(wire::check(
                &files[w],
                &lexed[w].1,
                &files[fr],
                &lexed[fr].1,
                protocol_lock,
            ));
        }
        _ => findings.push(Finding {
            path: "pruning/wire.rs".into(),
            line: 0,
            rule: "wire",
            msg: "pruning/wire.rs or net/framing.rs missing from the scanned tree".into(),
        }),
    }

    let obs_mod = files.iter().find(|f| f.path == "obs/mod.rs");
    findings.extend(metrics::check(files, &lexed, obs_mod));

    findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.into(), text: text.into() }
    }

    #[test]
    fn server_path_predicate() {
        assert!(is_server_path("net/framing.rs"));
        assert!(is_server_path("serve/tcp.rs"));
        assert!(is_server_path("coordinator/dispatch.rs"));
        assert!(is_server_path("obs/registry.rs"));
        assert!(is_server_path("sparse/packed.rs"));
        assert!(is_server_path("sparse/model.rs"));
        assert!(is_server_path("pruning/wire.rs"));
        assert!(!is_server_path("pruning/admm.rs"));
        assert!(!is_server_path("linalg/mod.rs"));
        assert!(!is_server_path("lint/mod.rs"));
    }

    #[test]
    fn allow_suppresses_exactly_one_finding() {
        let f = file(
            "net/x.rs",
            "fn f() {\n    // lint:allow(panic) startup-only, config already validated\n    a.unwrap();\n    b.unwrap();\n}\n",
        );
        let lx = lexer::lex(&f.text);
        let mut raw = Vec::new();
        panics::scan(&f, &lx, &mut raw);
        assert_eq!(raw.len(), 2);
        let out = apply_allows(&f.path, raw, &lx.allows);
        assert_eq!(out.len(), 1, "one suppressed, one kept: {out:?}");
        assert_eq!(out[0].line, 4);
    }

    #[test]
    fn unused_and_unreasoned_allows_are_findings() {
        let f = file("net/x.rs", "// lint:allow(panic) nothing here\nfn f() {}\n");
        let lx = lexer::lex(&f.text);
        let out = apply_allows(&f.path, Vec::new(), &lx.allows);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("unused"));

        let f2 = file("net/x.rs", "fn f() {\n    a.unwrap(); // lint:allow(panic)\n}\n");
        let lx2 = lexer::lex(&f2.text);
        let mut raw = Vec::new();
        panics::scan(&f2, &lx2, &mut raw);
        let out2 = apply_allows(&f2.path, raw, &lx2.allows);
        assert_eq!(out2.len(), 1);
        assert!(out2[0].msg.contains("requires a reason"), "{out2:?}");
    }

    #[test]
    fn unknown_allow_kind_is_reported() {
        let f = file("serve/x.rs", "// lint:allow(races) hmm\nfn f() {}\n");
        let lx = lexer::lex(&f.text);
        let out = apply_allows(&f.path, Vec::new(), &lx.allows);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("unknown lint:allow kind"));
    }
}
