//! Rule 1 — panic-freedom in server paths.
//!
//! Flags, in non-test tokens of watched files: method calls `.unwrap(`
//! and `.expect(`, and the macros `panic!`, `unreachable!`, `todo!`,
//! `unimplemented!`. Combinators like `unwrap_or_else` are distinct
//! identifiers and never match. Suppress a deliberate site with
//! `// lint:allow(panic) <reason>`.

use super::lexer::{Lexed, Tok, TokKind};
use super::{Finding, SourceFile};

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

pub fn scan(file: &SourceFile, lx: &Lexed, out: &mut Vec<Finding>) {
    let toks = &lx.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.test || t.kind != TokKind::Ident {
            continue;
        }
        let prev = i.checked_sub(1).map(|p| &toks[p]);
        let next = toks.get(i + 1);
        let is_punct = |t: Option<&Tok>, s: &str| {
            t.is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
        };
        if (t.text == "unwrap" || t.text == "expect")
            && is_punct(prev, ".")
            && is_punct(next, "(")
        {
            out.push(Finding {
                path: file.path.clone(),
                line: t.line,
                rule: "panic",
                msg: format!(
                    ".{}() in a server path — return an error (`?`/`bail!`) or justify with lint:allow(panic)",
                    t.text
                ),
            });
        } else if PANIC_MACROS.contains(&t.text.as_str()) && is_punct(next, "!") {
            out.push(Finding {
                path: file.path.clone(),
                line: t.line,
                rule: "panic",
                msg: format!(
                    "{}! in a server path — a transport/serve layer must not abort the process",
                    t.text
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn scan_src(src: &str) -> Vec<Finding> {
        let f = SourceFile { path: "net/fixture.rs".into(), text: src.into() };
        let lx = lex(src);
        let mut out = Vec::new();
        scan(&f, &lx, &mut out);
        out
    }

    #[test]
    fn flags_unwrap_expect_and_panic_macros() {
        let out = scan_src(
            "fn f() {\n    a.unwrap();\n    b.expect(\"msg\");\n    panic!(\"boom\");\n    unreachable!();\n}\n",
        );
        assert_eq!(out.len(), 4, "{out:?}");
        assert_eq!(out[0].line, 2);
        assert_eq!(out[3].line, 5);
    }

    #[test]
    fn combinators_and_test_code_pass() {
        let out = scan_src(
            "fn f() {\n    a.unwrap_or(0);\n    b.unwrap_or_else(|p| p.into_inner());\n    c.expect_err_helper();\n}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); panic!(\"fine in tests\"); }\n}\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn strings_and_comments_do_not_fire() {
        let out = scan_src("fn f() { let s = \"a.unwrap()\"; } // .unwrap() here too\n");
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn std_panic_paths_do_not_fire() {
        // `std::panic::catch_unwind` — `panic` not followed by `!`
        let out = scan_src("fn f() { let _ = std::panic::catch_unwind(|| 1); }\n");
        assert!(out.is_empty(), "{out:?}");
    }
}
