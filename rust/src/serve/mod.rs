//! `serve` — batched sparse-inference serving engine.
//!
//! This subsystem turns the pruned model from a benchmark artifact into
//! something that can answer generation traffic — the deployment payoff
//! the paper motivates ("sparsity reduces the storage and can accelerate
//! the inference"). It is layered as:
//!
//! * [`engine`] — token-level generation over the incremental KV-cache
//!   decode path ([`crate::model::Decoder`]): greedy and temperature/top-k
//!   sampling via the deterministic [`crate::util::Rng`]. One [`Engine`]
//!   wraps the dense weight backend, the CSR
//!   [`crate::model::SparseModel`], the packed N:M
//!   [`crate::sparse::NmModel`] (strided semi-structured kernels,
//!   bit-identical to CSR, per-layer CSR fallback for mixed
//!   checkpoints), or the int8 [`crate::sparse::Int8Model`] (quantized
//!   codes + per-column scales, ~25% of dense weight bytes) behind the
//!   same [`crate::model::DecodeOps`] seam; backends are `Send + Sync`
//!   so one engine is shared by reference across server threads.
//!   Construction sets the `alps_serve_backend_layers` /
//!   `alps_serve_weight_bytes` gauges
//!   (labelled `format=dense|csr|nm|int8`).
//! * [`batcher`] — a FIFO request queue with **continuous batching**:
//!   between decode steps, finished sequences are evicted and queued
//!   requests admitted, so the batch stays full without waiting for the
//!   slowest member. Admission prefill runs the whole prompt as one
//!   `[prompt, d_model]` pass per layer
//!   ([`crate::model::Decoder::prefill_batch`] — the SparseGPT-style
//!   layer-batched formulation), so admission costs O(layers) batched
//!   matmuls instead of O(prompt) single-row passes. Each decode step
//!   runs the whole batch's linear layers as one `[batch, d_model]`
//!   product, fanning across the matmul thread pool (`ALPS_THREADS` pins
//!   the pool width for reproducible benches).
//! * [`tcp`] — the serve wire protocol over the shared [`crate::net`]
//!   transport layer: the accept loop, connection cap, bounded line
//!   reads, and graceful drain-on-shutdown live in `net`; this module
//!   adds the line protocol, the scheduler thread driving decode steps
//!   over a shared `Mutex<Batcher>`, lock-free `GET /healthz` and
//!   `GET /metrics` (Prometheus text from the [`crate::obs`] registry),
//!   and client-disconnect cancellation (a connection that dies with
//!   generations in flight evicts them from the batcher instead of
//!   decoding to completion). See its module docs for the wire protocol.
//! * [`metrics`] — throughput and latency accounting on
//!   [`crate::util::Stats`]: tokens/s, per-step and per-token latency
//!   p50/p95/p99, per-request latency, admission prefill latency, mean
//!   batch occupancy. Latency windows tolerate NaN samples
//!   (`f64::total_cmp` ordering) instead of panicking the comparator.
//!   Every `record_*` also dual-writes the process-global
//!   `alps_serve_*` series in [`crate::obs`] through lock-free handles,
//!   so `/metrics` scrapes read fresh counters without the batcher lock.
//!
//! Per-token decode cost is O(context) attention + O(1) weight matmuls
//! thanks to the KV cache; re-running the full prefix each token (the
//! pre-serve eval path) is O(context) *matmuls*. `bench_serve` measures
//! both, the batched-vs-stepwise prefill speedup, the dense-vs-CSR
//! crossover at 50/70/90% sparsity, and healthz latency under concurrent
//! TCP load.
//!
//! ## CLI
//!
//! ```text
//! alps serve --model alps-base --weights pruned.bin
//!            [--format dense|csr|nm[:N:M]|int8] [--sparse]
//!            [--addr 127.0.0.1:7878] [--stdin] [--random]
//!            [--max-batch 8] [--max-conns 64] [--max-line 65536]
//!            [--max-new 32] [--temperature 0.0] [--top-k 0]
//! ```
//!
//! `--format` picks the weight backend: `dense`, `csr` (alias of the
//! older `--sparse` flag), `nm` for the packed N:M path (`nm` alone
//! means 2:4; `nm:4:8` etc. selects the pattern — non-conformant layers
//! fall back to CSR per layer), or `int8` to quantize every prunable
//! matrix at load (`crate::pruning::quantize` codes + per-column
//! scales). CSR and packed N:M produce bit-identical token streams, so
//! serving the same checkpoint under both formats and diffing outputs
//! is a valid (and CI-exercised) correctness check; `int8` matches
//! dense to ulp precision when the checkpoint already sits on the int8
//! grid (a `prune_quantize` artifact), and otherwise differs by
//! quantization error.
//!
//! Two std-only front-ends:
//!
//! * `--stdin`: read one prompt per line (whitespace-separated token ids),
//!   run everything through the continuous batcher, print `id: tokens`
//!   lines plus a metrics table. Good for scripted smoke tests.
//! * TCP line protocol (default, on `--addr`), served concurrently to up
//!   to `--max-conns` clients — see [`tcp`] for the full protocol
//!   (`queued <id>` acks, `run`/blank-line result waits, `stats`,
//!   `shutdown`, `GET /healthz`).
//!
//! ## Known limits (open items)
//!
//! * No per-request deadlines. Disconnect cancellation is in: a
//!   connection that tears down with requests in flight cancels them in
//!   the batcher ([`batcher::Batcher::cancel`]). A half-closed client
//!   that is still reading keeps the EOF-flush contract — its work
//!   decodes to completion and is delivered.
//! * One scheduler thread drives decode; the parallelism inside a step
//!   comes from the matmul pool. Multiple model replicas (one batcher
//!   per replica) would scale further.
//! * No TLS/auth on the TCP front-end; it trusts its network.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod tcp;

pub use batcher::{Batcher, Request, Response};
pub use engine::{sample_token, Engine, Generation, SamplingParams};
pub use metrics::ServeMetrics;
pub use tcp::TcpConfig;
