//! `serve` — batched sparse-inference serving engine.
//!
//! This subsystem turns the pruned model from a benchmark artifact into
//! something that can answer generation traffic — the deployment payoff
//! the paper motivates ("sparsity reduces the storage and can accelerate
//! the inference"). It is layered as:
//!
//! * [`engine`] — token-level generation over the incremental KV-cache
//!   decode path ([`crate::model::Decoder`]): greedy and temperature/top-k
//!   sampling via the deterministic [`crate::util::Rng`]. One [`Engine`]
//!   wraps either the dense weight backend or the CSR
//!   [`crate::model::SparseModel`] backend behind the same
//!   [`crate::model::DecodeOps`] seam.
//! * [`batcher`] — a FIFO request queue with **continuous batching**:
//!   between decode steps, finished sequences are evicted and queued
//!   requests admitted, so the batch stays full without waiting for the
//!   slowest member. Each step runs the whole batch's linear layers as one
//!   `[batch, d_model]` product, fanning across the matmul thread pool
//!   (`ALPS_THREADS` pins the pool width for reproducible benches).
//! * [`metrics`] — throughput and latency accounting on
//!   [`crate::util::Stats`]: tokens/s, per-step and per-token latency
//!   p50/p95/p99, per-request latency, mean batch occupancy.
//!
//! Per-token decode cost is O(context) attention + O(1) weight matmuls
//! thanks to the KV cache; re-running the full prefix each token (the
//! pre-serve eval path) is O(context) *matmuls*. `bench_serve` measures
//! both, plus the dense-vs-CSR crossover at 50/70/90% sparsity.
//!
//! ## CLI
//!
//! ```text
//! alps serve --model alps-base --weights pruned.bin [--sparse]
//!            [--addr 127.0.0.1:7878] [--stdin] [--random]
//!            [--max-batch 8] [--max-new 32] [--temperature 0.0] [--top-k 0]
//! ```
//!
//! Two std-only front-ends:
//!
//! * `--stdin`: read one prompt per line (whitespace-separated token ids),
//!   run everything through the continuous batcher, print `id: tokens`
//!   lines plus a metrics table. Good for scripted smoke tests.
//! * TCP line protocol (default, on `--addr`): each line is a prompt of
//!   token ids, acknowledged immediately with `queued <id>` (or
//!   `err - <msg>` — literal dash, no id — if the line doesn't parse).
//!   A blank line (or `run`, or EOF) flushes the accumulated requests
//!   through one batched generation and writes one `ok <id> <tokens...>`
//!   line per request, or `err <id> <msg>` for requests rejected at
//!   prefill; a flush with nothing queued answers `err - no pending
//!   requests`. A leading `GET ` line gets a minimal HTTP 200 health/info
//!   response instead, so `curl http://addr/healthz` works.
//!
//! ## Known limits (open items)
//!
//! * The TCP front-end serves one connection at a time (std-only, no
//!   threading yet): an idle connected client delays later clients,
//!   including health probes. Batching happens within a connection.
//! * Prompt prefill at admission runs token-by-token through the decode
//!   step (exact, O(prompt) single-row passes). A batched multi-row
//!   prefill (one `[prompt, d]` pass per layer) would cut admission
//!   latency substantially; the decode seam already supports it.

pub mod batcher;
pub mod engine;
pub mod metrics;

pub use batcher::{Batcher, Request, Response};
pub use engine::{sample_token, Engine, Generation, SamplingParams};
pub use metrics::ServeMetrics;
