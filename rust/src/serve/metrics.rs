//! Serving metrics: decode throughput and latency histograms — the
//! numbers `bench_serve` and the `serve` CLI report (tokens/s,
//! p50/p95/p99, batch occupancy). Per-step samples are stored once as
//! `(secs, batch)` pairs in a sliding window ([`STEP_WINDOW`] most recent
//! steps, likewise for request latencies), so a long-lived server holds
//! bounded memory; latency percentiles cover that window while the
//! throughput counters cover the full lifetime.
//!
//! [`ServeMetrics`] is also a **view over the [`crate::obs`] registry**:
//! every `record_*` dual-writes the process-global `alps_serve_*`
//! counters/histograms through pre-registered lock-free handles, so a
//! `GET /metrics` scrape reads fresh numbers *without* taking the batcher
//! lock the scheduler holds (scrape-under-load never blocks decoding).
//! The sliding windows stay local — exact percentiles for the CLI report;
//! bucketed histograms for Prometheus.

use crate::obs::{Counter, Gauge, Histogram};
use crate::util::table::Table;
use crate::util::Stats;
use std::collections::VecDeque;

/// Latency percentiles are computed over the most recent this-many decode
/// steps — bounded memory and report cost on long-lived servers.
pub const STEP_WINDOW: usize = 4096;

/// Registry handles behind one [`ServeMetrics`] instance. Registration
/// is idempotent, so every instance in a process shares the same
/// underlying `alps_serve_*` series (process totals — the Prometheus
/// contract), while the window-based percentiles stay per-instance.
struct ObsHandles {
    tokens: Counter,
    steps: Counter,
    requests: Counter,
    cancelled: Counter,
    prefills: Counter,
    prompt_tokens: Counter,
    batch_occupancy: Gauge,
    step_secs: Histogram,
    request_secs: Histogram,
    prefill_secs: Histogram,
}

impl ObsHandles {
    fn acquire() -> ObsHandles {
        let r = crate::obs::global();
        let edges = &crate::obs::LATENCY_EDGES;
        ObsHandles {
            tokens: r.counter("alps_serve_tokens_total", "decode tokens generated", &[]),
            steps: r.counter("alps_serve_steps_total", "batched decode steps", &[]),
            requests: r.counter("alps_serve_requests_total", "requests completed", &[]),
            cancelled: r
                .counter("alps_serve_cancelled_total", "requests cancelled (client gone)", &[]),
            prefills: r.counter("alps_serve_prefills_total", "admission prefills", &[]),
            prompt_tokens: r
                .counter("alps_serve_prompt_tokens_total", "prompt tokens prefilled", &[]),
            batch_occupancy: r
                .gauge("alps_serve_batch_occupancy", "tokens produced by the last step", &[]),
            step_secs: r.histogram("alps_serve_step_seconds", "decode step latency", &[], edges),
            request_secs: r.histogram(
                "alps_serve_request_seconds",
                "end-to-end request latency (queue + prefill + decode)",
                &[],
                edges,
            ),
            prefill_secs: r
                .histogram("alps_serve_prefill_seconds", "admission prefill latency", &[], edges),
        }
    }
}

/// Accumulated serving counters for one engine run.
pub struct ServeMetrics {
    /// Sliding window of batched decode steps: (seconds, tokens produced).
    steps: VecDeque<(f64, usize)>,
    steps_total: usize,
    /// Sliding window of per-request end-to-end latencies (seconds).
    request_secs: VecDeque<f64>,
    /// Sliding window of admission prefill latencies (seconds).
    prefill_secs: VecDeque<f64>,
    tokens_generated: usize,
    requests_completed: usize,
    requests_cancelled: usize,
    prompts_prefilled: usize,
    prompt_tokens: usize,
    decode_wall_secs: f64,
    obs: ObsHandles,
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            steps: VecDeque::new(),
            steps_total: 0,
            request_secs: VecDeque::new(),
            prefill_secs: VecDeque::new(),
            tokens_generated: 0,
            requests_completed: 0,
            requests_cancelled: 0,
            prompts_prefilled: 0,
            prompt_tokens: 0,
            decode_wall_secs: 0.0,
            obs: ObsHandles::acquire(),
        }
    }

    /// Record one batched decode step that produced `batch` tokens.
    pub fn record_step(&mut self, batch: usize, secs: f64) {
        if self.steps.len() == STEP_WINDOW {
            self.steps.pop_front();
        }
        self.steps.push_back((secs, batch));
        self.steps_total += 1;
        self.tokens_generated += batch;
        self.decode_wall_secs += secs;
        self.obs.tokens.add(batch as u64);
        self.obs.steps.inc();
        self.obs.batch_occupancy.set(batch as f64);
        self.obs.step_secs.observe(secs);
    }

    /// Record one completed request's end-to-end latency (queue + prefill
    /// + decode).
    pub fn record_request(&mut self, total_secs: f64) {
        if self.request_secs.len() == STEP_WINDOW {
            self.request_secs.pop_front();
        }
        self.request_secs.push_back(total_secs);
        self.requests_completed += 1;
        self.obs.requests.inc();
        self.obs.request_secs.observe(total_secs);
    }

    /// Record one admission prefill of a `tokens`-long prompt.
    pub fn record_prefill(&mut self, tokens: usize, secs: f64) {
        if self.prefill_secs.len() == STEP_WINDOW {
            self.prefill_secs.pop_front();
        }
        self.prefill_secs.push_back(secs);
        self.prompts_prefilled += 1;
        self.prompt_tokens += tokens;
        self.obs.prefills.inc();
        self.obs.prompt_tokens.add(tokens as u64);
        self.obs.prefill_secs.observe(secs);
    }

    pub fn prompts_prefilled(&self) -> usize {
        self.prompts_prefilled
    }

    pub fn prompt_tokens(&self) -> usize {
        self.prompt_tokens
    }

    /// Admission prefill latency percentile in milliseconds (over the most
    /// recent [`STEP_WINDOW`] prompts).
    pub fn prefill_latency_ms(&self, q: f64) -> f64 {
        let window: Vec<f64> = self.prefill_secs.iter().copied().collect();
        Stats::from_samples(&window).percentile(q) * 1e3
    }

    pub fn tokens_generated(&self) -> usize {
        self.tokens_generated
    }

    pub fn requests_completed(&self) -> usize {
        self.requests_completed
    }

    /// Record one request evicted because its client disconnected.
    pub fn record_cancelled(&mut self) {
        self.requests_cancelled += 1;
        self.obs.cancelled.inc();
    }

    pub fn requests_cancelled(&self) -> usize {
        self.requests_cancelled
    }

    pub fn steps(&self) -> usize {
        self.steps_total
    }

    /// Decode throughput over the time actually spent in decode steps.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.decode_wall_secs
    }

    pub fn mean_batch(&self) -> f64 {
        if self.steps_total == 0 {
            return f64::NAN;
        }
        self.tokens_generated as f64 / self.steps_total as f64
    }

    /// Per-token decode latency percentile in milliseconds over the step
    /// window: every token emitted by a step observed that step's latency,
    /// so steps are weighted by their batch size (nearest-rank over the
    /// window's token multiset).
    pub fn token_latency_ms(&self, q: f64) -> f64 {
        if self.steps.is_empty() {
            return f64::NAN;
        }
        let mut sorted: Vec<(f64, usize)> = self.steps.iter().copied().collect();
        // total order even in the presence of NaN samples (a NaN-poisoned
        // comparator panicked sort_by here); NaNs order after every finite
        // latency, so they only surface at the extreme percentiles
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        let window_tokens: usize = sorted.iter().map(|(_, b)| b).sum();
        let target = (q / 100.0) * window_tokens as f64;
        let mut cum = 0usize;
        for (secs, batch) in &sorted {
            cum += batch;
            if cum as f64 >= target {
                return secs * 1e3;
            }
        }
        sorted.last().map_or(f64::NAN, |(secs, _)| secs * 1e3)
    }

    /// End-to-end request latency percentile in milliseconds (over the
    /// most recent [`STEP_WINDOW`] requests).
    pub fn request_latency_ms(&self, q: f64) -> f64 {
        let window: Vec<f64> = self.request_secs.iter().copied().collect();
        Stats::from_samples(&window).percentile(q) * 1e3
    }

    /// Render the standard report table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"]);
        t.row(&["tokens/s (decode)".to_string(), format!("{:.1}", self.tokens_per_sec())]);
        t.row(&["tokens generated".to_string(), self.tokens_generated.to_string()]);
        t.row(&["requests completed".to_string(), self.requests_completed.to_string()]);
        t.row(&["requests cancelled".to_string(), self.requests_cancelled.to_string()]);
        t.row(&["decode steps".to_string(), self.steps().to_string()]);
        t.row(&["mean batch".to_string(), format!("{:.2}", self.mean_batch())]);
        for q in [50.0, 95.0, 99.0] {
            t.row(&[
                format!("token p{q:.0} ms"),
                format!("{:.3}", self.token_latency_ms(q)),
            ]);
        }
        for q in [50.0, 99.0] {
            t.row(&[
                format!("request p{q:.0} ms"),
                format!("{:.3}", self.request_latency_ms(q)),
            ]);
        }
        t.row(&["prompts prefilled".to_string(), self.prompts_prefilled.to_string()]);
        for q in [50.0, 99.0] {
            t.row(&[
                format!("prefill p{q:.0} ms"),
                format!("{:.3}", self.prefill_latency_ms(q)),
            ]);
        }
        t.render()
    }

    /// One-line summary for server logs.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs, {} toks, {:.1} tok/s, token p50/p95/p99 {:.2}/{:.2}/{:.2} ms, \
             request p50/p99 {:.1}/{:.1} ms",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_per_sec(),
            self.token_latency_ms(50.0),
            self.token_latency_ms(95.0),
            self.token_latency_ms(99.0),
            self.request_latency_ms(50.0),
            self.request_latency_ms(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let mut m = ServeMetrics::new();
        m.record_step(2, 0.010);
        m.record_step(4, 0.020);
        m.record_step(1, 0.030);
        m.record_request(0.5);
        m.record_request(1.5);
        m.record_cancelled();
        assert_eq!(m.tokens_generated(), 7);
        assert_eq!(m.steps(), 3);
        assert_eq!(m.requests_completed(), 2);
        assert_eq!(m.requests_cancelled(), 1);
        assert!((m.tokens_per_sec() - 7.0 / 0.060).abs() < 1e-9);
        assert!((m.mean_batch() - 7.0 / 3.0).abs() < 1e-9);
        // token multiset (ms): 10,10,20,20,20,20,30 — weighted nearest-rank
        assert!((m.token_latency_ms(50.0) - 20.0).abs() < 1e-9);
        assert!((m.token_latency_ms(99.0) - 30.0).abs() < 1e-9);
        assert!((m.token_latency_ms(1.0) - 10.0).abs() < 1e-9);
        assert!((m.request_latency_ms(50.0) - 1000.0).abs() < 1e-9);
        let r = m.render();
        assert!(r.contains("tokens/s"));
        assert!(m.summary().contains("2 reqs"));
    }

    #[test]
    fn step_window_bounds_memory_not_counters() {
        let mut m = ServeMetrics::new();
        for _ in 0..(STEP_WINDOW + 100) {
            m.record_step(1, 0.001);
        }
        assert_eq!(m.steps(), STEP_WINDOW + 100);
        assert_eq!(m.tokens_generated(), STEP_WINDOW + 100);
        assert!((m.token_latency_ms(50.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::new();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert!(m.token_latency_ms(50.0).is_nan());
        assert!(m.prefill_latency_ms(50.0).is_nan());
        assert!(m.mean_batch().is_nan());
        let _ = m.render();
    }

    #[test]
    fn nan_latency_samples_do_not_panic() {
        // regression: a NaN step latency panicked the partial_cmp sort in
        // token_latency_ms (and the Stats sort behind request_latency_ms)
        let mut m = ServeMetrics::new();
        m.record_step(1, f64::NAN);
        m.record_step(1, 0.002);
        m.record_step(1, 0.001);
        // NaN orders last, so the median over {1ms, 2ms, NaN} stays finite
        assert!((m.token_latency_ms(50.0) - 2.0).abs() < 1e-9);
        m.record_request(f64::NAN);
        m.record_request(0.5);
        let _ = m.request_latency_ms(50.0);
        m.record_prefill(4, f64::NAN);
        m.record_prefill(4, 0.001);
        let _ = m.prefill_latency_ms(50.0);
        let _ = m.render();
        let _ = m.summary();
    }

    #[test]
    fn registry_view_reflects_records() {
        // counters are process-global (tests share them), so assert the
        // families exist and are non-zero rather than exact values
        let mut m = ServeMetrics::new();
        m.record_step(3, 0.01);
        m.record_request(0.2);
        m.record_prefill(5, 0.003);
        let text = crate::obs::global().render();
        assert!(text.contains("# TYPE alps_serve_tokens_total counter"), "{text}");
        assert!(text.contains("alps_serve_step_seconds_bucket"));
        assert!(text.contains("alps_serve_request_seconds_count"));
        assert!(text.contains("alps_serve_prefill_seconds_sum"));
        assert!(text.contains("alps_serve_batch_occupancy"));
    }

    #[test]
    fn prefill_counters_and_percentiles() {
        let mut m = ServeMetrics::new();
        m.record_prefill(16, 0.004);
        m.record_prefill(8, 0.002);
        assert_eq!(m.prompts_prefilled(), 2);
        assert_eq!(m.prompt_tokens(), 24);
        assert!((m.prefill_latency_ms(50.0) - 3.0).abs() < 1e-9);
        assert!(m.render().contains("prefill p50"));
    }
}
