//! Serve front-end: the line-oriented generation protocol over the shared
//! [`crate::net`] transport layer.
//!
//! PR 2 grew this module into a full threaded TCP server; PR 4 extracted
//! the reusable transport half (accept loop, connection cap, refusal
//! pool, bounded line reads, graceful shutdown drain) into
//! [`crate::net`], so this file now owns only the serve *protocol*:
//!
//! * All connections submit into one `Mutex<Batcher>`; a dedicated
//!   scheduler thread runs decode steps whenever work is queued (woken by
//!   a condvar on submit), so requests from different connections share
//!   the decode batch. Finished responses are routed back to the owning
//!   connection over per-connection mpsc channels.
//! * `GET /healthz` is answered from static model info plus the
//!   [`crate::net::NetServer`] connection gauge, and `GET /metrics`
//!   renders the process-global [`crate::obs`] registry (Prometheus text:
//!   the `alps_serve_*` and `alps_net_*` families) — neither touches the
//!   batcher lock, so probes and scrapes stay responsive while decode
//!   steps run, even with the server at its connection cap.
//! * A disconnected client's outstanding generations are **cancelled**:
//!   when a connection tears down with requests still in flight (read or
//!   write error — the client is gone), their sequences are evicted from
//!   the batcher instead of decoding to completion for nobody.
//! * Graceful shutdown: the `shutdown` protocol line (or an accept-loop
//!   exit) triggers the net-layer shutdown; the scheduler drains all
//!   in-flight generations, reader loops notice within one read-timeout
//!   tick, and `serve` returns the final metrics report.
//!
//! ## Wire protocol (line-oriented)
//!
//! * A line of whitespace-separated token ids queues a generation,
//!   acknowledged `queued <id>` (ids are global across connections).
//!   Generation starts immediately — no flush needed to begin work.
//! * A blank line, `run`, or EOF (client half-close) waits for all of
//!   this connection's outstanding requests and writes one
//!   `ok <id> <tokens...>` or `err <id> <msg>` line per request (sorted
//!   by id); an explicit flush with nothing outstanding answers
//!   `err - no pending requests`.
//! * `stats` answers one `ok - <metrics summary>` line.
//! * `shutdown` answers `ok shutdown` and stops the whole server after
//!   draining in-flight work.
//! * A first line starting with `GET ` gets a minimal HTTP 200 response
//!   and closes: `/metrics` serves the Prometheus exposition, anything
//!   else the health JSON (so `curl http://addr/healthz` works).
//! * Lines longer than [`TcpConfig::max_line_bytes`] get `err - line too
//!   long` and the connection is closed.

use super::batcher::{Batcher, Response};
use super::engine::{Engine, SamplingParams};
use crate::net::framing::{read_line_bounded, LineRead};
use crate::net::server::{
    finish_refusal, request_path, respond_http, respond_http_json, write_http_json,
    write_http_response,
};
use crate::net::{lock, ConnHandler, NetServer, ServerConfig, READ_POLL, WRITE_TIMEOUT};
use anyhow::{Context as _, Result};
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduler condvar timeout while idle (also bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(50);
/// Slice for response-wait polling during a flush.
const RECV_POLL: Duration = Duration::from_millis(100);
/// Overall cap on one flush's wait for generations.
const FLUSH_TIMEOUT: Duration = Duration::from_secs(120);
/// Once shutdown begins, a flush waits at most this long for the drain.
const SHUTDOWN_DRAIN: Duration = Duration::from_secs(5);
/// How long an over-cap refusal waits to classify the client (healthz
/// probe vs line-protocol client) before giving up on it.
const REFUSE_READ_TIMEOUT: Duration = Duration::from_millis(500);

/// Front-end configuration (CLI flags `--max-batch`, `--max-conns`,
/// `--max-line`).
#[derive(Clone, Debug)]
pub struct TcpConfig {
    /// Decode batch width of the shared batcher.
    pub max_batch: usize,
    /// Concurrent connection cap; excess connections are refused.
    pub max_conns: usize,
    /// Longest accepted request line in bytes.
    pub max_line_bytes: usize,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig { max_batch: 8, max_conns: 64, max_line_bytes: 64 * 1024 }
    }
}

/// Parse a prompt line of whitespace-separated token ids.
pub fn parse_prompt(line: &str) -> Result<Vec<u16>> {
    line.split_whitespace()
        .map(|t| t.parse::<u16>().with_context(|| format!("bad token id '{t}'")))
        .collect()
}

/// Render tokens as the wire format (space-separated ids).
pub fn fmt_tokens(tokens: &[u16]) -> String {
    tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

/// State shared between the connection threads and the scheduler thread.
struct Shared<'e, 'm> {
    engine: &'e Engine<'m>,
    batcher: Mutex<Batcher<'e, 'm>>,
    /// Notified on submit so the scheduler wakes without polling.
    work: Condvar,
    /// Reply route per in-flight request id.
    replies: Mutex<HashMap<u64, mpsc::Sender<Response>>>,
    /// Connection lifecycle + shutdown flag live in the net layer.
    net: NetServer,
}

impl Shared<'_, '_> {
    fn begin_shutdown(&self) {
        self.net.shutdown();
        self.work.notify_all();
    }
}

/// The serve protocol plugged into the net accept loop.
struct FrontEnd<'a, 'e, 'm> {
    shared: &'a Shared<'e, 'm>,
    params: &'a SamplingParams,
    cfg: &'a TcpConfig,
}

impl ConnHandler for FrontEnd<'_, '_, '_> {
    fn handle(&self, stream: TcpStream) -> Result<()> {
        handle_conn(stream, self.shared, self.params, self.cfg)
    }

    /// Over-cap connections: `GET` health probes are still answered
    /// (monitoring matters most when the server is saturated); everything
    /// else gets the refusal line. One bounded read with a short deadline
    /// classifies the client, then the write side is half-closed and
    /// pipelined input briefly drained — closing with unread inbound data
    /// buffered can RST the reply away before the client reads it.
    fn refuse(&self, stream: TcpStream, cap: usize) {
        let mut st = stream;
        let _ = st.set_read_timeout(Some(REFUSE_READ_TIMEOUT));
        let _ = st.set_write_timeout(Some(WRITE_TIMEOUT));
        let mut first = [0u8; 512];
        let mut have = 0usize;
        // classify from up to a few bounded reads: "GET " can arrive split
        // across TCP segments; a GET keeps reading to the end of its
        // request line (the path routes /metrics vs healthz), anything
        // else stops at 4 bytes, and a client that stalls past the read
        // deadline is refused as silent
        for _ in 0..8 {
            match std::io::Read::read(&mut st, &mut first[have..]) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    have += n;
                    let got = &first[..have];
                    if got.contains(&b'\n')
                        || (have >= 4 && !got.starts_with(b"GET "))
                        || have == first.len()
                    {
                        break;
                    }
                }
            }
        }
        if first[..have].starts_with(b"GET ") {
            let line = String::from_utf8_lossy(&first[..have]);
            if request_path(line.lines().next().unwrap_or("")) == "/metrics" {
                // a saturated server is exactly when scrapes matter most
                let body = crate::obs::global().render();
                let _ = write_http_response(&mut st, crate::obs::prometheus::CONTENT_TYPE, &body);
            } else {
                let m = self.shared.engine.model();
                let body = format!(
                    "{{\"model\":\"{}\",\"backend\":\"{}\",\"connections\":{},\
                     \"at_capacity\":true}}\n",
                    m.cfg.name,
                    self.shared.engine.label(),
                    self.shared.net.connections(),
                );
                let _ = write_http_json(&mut st, &body);
            }
        } else {
            let _ = writeln!(st, "err - connection limit reached ({cap})");
        }
        finish_refusal(&st);
    }
}

/// Serve the line protocol on `listener` until a client sends `shutdown`.
/// Returns the final metrics report. The listener may be bound to port 0;
/// tests read the actual address back via `TcpListener::local_addr`
/// before handing the listener in.
pub fn serve(
    listener: TcpListener,
    engine: &Engine,
    params: &SamplingParams,
    cfg: &TcpConfig,
) -> Result<String> {
    let shared = Shared {
        engine,
        batcher: Mutex::new(Batcher::new(engine, cfg.max_batch)),
        work: Condvar::new(),
        replies: Mutex::new(HashMap::new()),
        net: NetServer::new(ServerConfig {
            max_conns: cfg.max_conns,
            ..Default::default()
        }),
    };
    let front = FrontEnd { shared: &shared, params, cfg };
    std::thread::scope(|s| {
        s.spawn(|| scheduler(&shared));
        if let Err(e) = shared.net.run(listener, &front) {
            eprintln!("[serve] front-end error: {e}");
        }
        // net.run raised the shutdown flag; wake the scheduler so it
        // drains and exits, then the scope joins it
        shared.begin_shutdown();
    });
    let report = lock(&shared.batcher).metrics.render();
    Ok(report)
}

/// Scheduler thread: run decode steps whenever work is queued, route
/// finished responses to their connections. Holds the batcher lock only
/// for the duration of one step, so submissions interleave between steps.
fn scheduler(shared: &Shared) {
    loop {
        let mut b = lock(&shared.batcher);
        while b.is_idle() {
            if shared.net.is_shutdown() {
                return;
            }
            b = match shared.work.wait_timeout(b, IDLE_POLL) {
                Ok((g, _)) => g,
                Err(p) => p.into_inner().0,
            };
        }
        let finished = match b.step() {
            Ok(f) => f,
            Err(e) => {
                // structural failure (missing weight): nothing further can
                // decode — shut the server down rather than spin on errors
                eprintln!("[serve] scheduler decode error, shutting down: {e}");
                drop(b);
                shared.begin_shutdown();
                return;
            }
        };
        drop(b);
        if finished.is_empty() {
            continue;
        }
        let mut replies = lock(&shared.replies);
        for r in finished {
            if let Some(tx) = replies.remove(&r.id) {
                let _ = tx.send(r); // receiver gone => connection closed
            }
        }
    }
}

/// Wait for all of this connection's outstanding generations and write
/// one result line per request (sorted by id). Requests not done by the
/// deadline (shortened once a server shutdown begins) are reported as
/// timed out and their reply routes dropped; a response arriving after
/// its timeout report is discarded on the next flush rather than emitted
/// as a stray extra line.
fn flush_results(
    stream: &mut TcpStream,
    rx: &mpsc::Receiver<Response>,
    outstanding: &mut HashSet<u64>,
    shared: &Shared,
) -> Result<()> {
    let mut ready: Vec<Response> = Vec::new();
    let deadline = Instant::now() + FLUSH_TIMEOUT;
    let mut drain_deadline: Option<Instant> = None;
    while !outstanding.is_empty() {
        let now = Instant::now();
        if shared.net.is_shutdown() && drain_deadline.is_none() {
            drain_deadline = Some(now + SHUTDOWN_DRAIN);
        }
        let until = drain_deadline.map_or(deadline, |d| d.min(deadline));
        if now >= until {
            break;
        }
        match rx.recv_timeout(RECV_POLL.min(until - now)) {
            Ok(r) => {
                if outstanding.remove(&r.id) {
                    ready.push(r);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    ready.sort_by_key(|r| r.id);
    for r in ready {
        match r.error {
            Some(e) => writeln!(stream, "err {} {e}", r.id)?,
            None => writeln!(stream, "ok {} {}", r.id, fmt_tokens(&r.tokens))?,
        }
    }
    // timed-out requests are cancelled outright — nobody is waiting for
    // them anymore, so their sequences must not keep decoding to
    // completion in a batch slot. Cancel before writing the error lines:
    // a failed write must not leave the generations running (the ids are
    // already out of `outstanding`, so the teardown won't see them).
    let timed_out: Vec<u64> = outstanding.drain().collect();
    if !timed_out.is_empty() {
        let mut b = lock(&shared.batcher);
        let mut replies = lock(&shared.replies);
        for id in &timed_out {
            replies.remove(id);
            b.cancel(*id);
        }
    }
    for id in timed_out {
        writeln!(stream, "err {id} timed out waiting for generation")?;
    }
    println!("[serve] {}", lock(&shared.batcher).metrics.summary());
    Ok(())
}

/// One connection: protocol loop + guaranteed teardown. Any request still
/// outstanding when the loop ends — a write error means the client is
/// gone, a read error means it vanished mid-line — is cancelled in the
/// batcher so its sequence stops decoding, and its reply route dropped so
/// the shared map does not accumulate dead entries.
fn handle_conn(
    stream: TcpStream,
    shared: &Shared,
    params: &SamplingParams,
    cfg: &TcpConfig,
) -> Result<()> {
    let (tx, rx) = mpsc::channel::<Response>();
    let mut outstanding: HashSet<u64> = HashSet::new();
    let res = conn_loop(stream, shared, params, cfg, &tx, &rx, &mut outstanding);
    if !outstanding.is_empty() {
        // lock order matches the submit path: batcher, then replies
        let mut b = lock(&shared.batcher);
        let mut replies = lock(&shared.replies);
        for id in outstanding.drain() {
            replies.remove(&id);
            b.cancel(id);
        }
    }
    res
}

#[allow(clippy::too_many_arguments)]
fn conn_loop(
    stream: TcpStream,
    shared: &Shared,
    params: &SamplingParams,
    cfg: &TcpConfig,
    tx: &mpsc::Sender<Response>,
    rx: &mpsc::Receiver<Response>,
    outstanding: &mut HashSet<u64>,
) -> Result<()> {
    stream.set_read_timeout(Some(READ_POLL)).context("setting read timeout")?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut stream = stream;
    let mut first = true;
    loop {
        let shutdown_flag = shared.net.shutdown_flag();
        let line = match read_line_bounded(&mut reader, cfg.max_line_bytes, shutdown_flag)? {
            LineRead::Line(l) => l,
            LineRead::TooLong => {
                writeln!(stream, "err - line too long (max {} bytes)", cfg.max_line_bytes)?;
                break;
            }
            // EOF is an implicit flush (PR 1 contract: `printf .. | nc`
            // with no trailing blank line still gets its results; the
            // client half-closes and keeps reading). Server shutdown is
            // too: the drain decodes acked work to completion, so deliver
            // it instead of dropping it (flush_results shortens its
            // deadline once shutdown is flagged). Best-effort either way:
            // a fully-gone client fails the writes, and the teardown in
            // `handle_conn` cancels whatever is then still outstanding.
            LineRead::Eof | LineRead::Shutdown => {
                if !outstanding.is_empty() {
                    let _ = flush_results(&mut stream, rx, outstanding, shared);
                }
                break;
            }
        };
        if first && line.starts_with("GET ") {
            // /metrics renders the process-global obs registry (no batcher
            // lock — scrapes stay responsive mid-decode); any other path
            // answers the healthz shape, likewise lock-free
            if request_path(&line) == "/metrics" {
                let body = crate::obs::global().render();
                let ctype = crate::obs::prometheus::CONTENT_TYPE;
                respond_http(
                    &mut reader,
                    &mut stream,
                    cfg.max_line_bytes,
                    shutdown_flag,
                    ctype,
                    &body,
                )?;
                break;
            }
            let m = shared.engine.model();
            let body = format!(
                "{{\"model\":\"{}\",\"backend\":\"{}\",\"vocab\":{},\"seq_len\":{},\
                 \"connections\":{},\"max_batch\":{}}}\n",
                m.cfg.name,
                shared.engine.label(),
                m.cfg.vocab,
                m.cfg.seq_len,
                shared.net.connections(),
                cfg.max_batch,
            );
            respond_http_json(&mut reader, &mut stream, cfg.max_line_bytes, shutdown_flag, &body)?;
            break;
        }
        first = false;
        let trimmed = line.trim();
        if trimmed == "shutdown" {
            writeln!(stream, "ok shutdown")?;
            shared.begin_shutdown();
            break;
        }
        if trimmed == "stats" {
            let summary = lock(&shared.batcher).metrics.summary();
            writeln!(stream, "ok - {summary}")?;
            continue;
        }
        let flush = trimmed.is_empty() || trimmed == "run";
        if !flush {
            match parse_prompt(trimmed) {
                Ok(p) => {
                    // register the reply route while still holding the
                    // batcher lock: the scheduler cannot complete the
                    // request before the route exists because completing
                    // it needs this same lock
                    let id = {
                        let mut b = lock(&shared.batcher);
                        let id = b.submit(p, params.clone());
                        lock(&shared.replies).insert(id, tx.clone());
                        id
                    };
                    shared.work.notify_all();
                    outstanding.insert(id);
                    writeln!(stream, "queued {id}")?;
                }
                Err(e) => writeln!(stream, "err - {e}")?,
            }
        } else if outstanding.is_empty() {
            // answer rather than leaving a client blocked on read
            writeln!(stream, "err - no pending requests")?;
        } else {
            flush_results(&mut stream, rx, outstanding, shared)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;
    use crate::util::Timer;
    use std::io::Read;
    use std::net::SocketAddr;

    fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        (BufReader::new(s.try_clone().unwrap()), s)
    }

    fn send(w: &mut TcpStream, line: &str) {
        writeln!(w, "{line}").unwrap();
    }

    fn recv(r: &mut BufReader<TcpStream>) -> String {
        let mut l = String::new();
        r.read_line(&mut l).unwrap();
        l.trim_end().to_string()
    }

    #[test]
    fn concurrent_clients_served_with_responsive_healthz() {
        // the tentpole acceptance: >= 4 concurrent TCP clients all get
        // answers while healthz probes stay responsive throughout
        let m = random_model(40);
        let e = Engine::dense(&m).unwrap();
        let params = SamplingParams { max_new_tokens: 6, ..Default::default() };
        let cfg = TcpConfig::default();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(listener, &e, &params, &cfg).unwrap());
            let clients: Vec<_> = (0..5)
                .map(|ci| {
                    s.spawn(move || {
                        let (mut r, mut w) = connect(addr);
                        for _ in 0..2 {
                            send(&mut w, "1 2 3");
                            let ack = recv(&mut r);
                            assert!(ack.starts_with("queued "), "client {ci}: {ack}");
                        }
                        send(&mut w, "run");
                        let mut results = Vec::new();
                        for _ in 0..2 {
                            let l = recv(&mut r);
                            assert!(l.starts_with("ok "), "client {ci}: {l}");
                            results.push(l.split_once(' ').unwrap().1.to_string());
                        }
                        results
                    })
                })
                .collect();
            // healthz probes while the load is in flight: must answer
            // without queueing behind any client connection
            for _ in 0..3 {
                let t = Timer::start();
                let (mut r, mut w) = connect(addr);
                write!(w, "GET /healthz HTTP/1.1\r\n\r\n").unwrap();
                let mut resp = String::new();
                r.read_to_string(&mut resp).unwrap();
                assert!(resp.starts_with("HTTP/1.1 200 OK"), "healthz: {resp}");
                assert!(resp.contains("\"connections\""));
                assert!(t.elapsed_secs() < 1.0, "healthz took {:.3}s", t.elapsed_secs());
            }
            // a /metrics scrape mid-load must answer promptly too (it
            // renders the obs registry without the batcher lock)
            {
                let t = Timer::start();
                let (mut r, mut w) = connect(addr);
                write!(w, "GET /metrics HTTP/1.1\r\n\r\n").unwrap();
                let mut resp = String::new();
                r.read_to_string(&mut resp).unwrap();
                assert!(resp.starts_with("HTTP/1.1 200 OK"), "metrics: {resp}");
                assert!(resp.contains("# TYPE alps_serve_tokens_total counter"), "{resp}");
                assert!(t.elapsed_secs() < 1.0, "metrics took {:.3}s", t.elapsed_secs());
            }
            let all: Vec<Vec<String>> = clients.into_iter().map(|c| c.join().unwrap()).collect();
            assert_eq!(all.len(), 5);
            // same greedy prompt everywhere => identical generations
            let first_tokens = all[0][0].split_once(' ').unwrap().1.to_string();
            for res in &all {
                assert_eq!(res.len(), 2);
                for line in res {
                    assert_eq!(line.split_once(' ').unwrap().1, first_tokens);
                }
            }
            let (mut r, mut w) = connect(addr);
            send(&mut w, "shutdown");
            assert_eq!(recv(&mut r), "ok shutdown");
            let report = server.join().unwrap();
            assert!(report.contains("tokens/s"), "report: {report}");
        });
    }

    #[test]
    fn oversized_line_rejected_with_bounded_memory() {
        let m = random_model(41);
        let e = Engine::dense(&m).unwrap();
        let params = SamplingParams::default();
        let cfg = TcpConfig { max_line_bytes: 64, ..Default::default() };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(listener, &e, &params, &cfg).unwrap());
            let (mut r, mut w) = connect(addr);
            let huge = "7 ".repeat(4096);
            send(&mut w, &huge);
            let l = recv(&mut r);
            assert!(l.starts_with("err - line too long"), "got: {l}");
            // server closed the connection after rejecting
            let mut rest = String::new();
            assert_eq!(r.read_to_string(&mut rest).unwrap(), 0);
            let (mut r2, mut w2) = connect(addr);
            send(&mut w2, "shutdown");
            assert_eq!(recv(&mut r2), "ok shutdown");
            server.join().unwrap();
        });
    }

    #[test]
    fn connection_cap_refuses_excess_clients() {
        let m = random_model(42);
        let e = Engine::dense(&m).unwrap();
        let params = SamplingParams::default();
        let cfg = TcpConfig { max_conns: 1, ..Default::default() };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(listener, &e, &params, &cfg).unwrap());
            let (mut r1, mut w1) = connect(addr);
            send(&mut w1, "1 2");
            assert!(recv(&mut r1).starts_with("queued "));
            // second client is over the cap: refused with an error line
            let (mut r2, mut w2) = connect(addr);
            send(&mut w2, "4 5"); // classifying read sees a non-GET line
            let l = recv(&mut r2);
            assert!(l.starts_with("err - connection limit reached"), "got: {l}");
            // healthz must still be answered at the cap
            let (mut r3, mut w3) = connect(addr);
            write!(w3, "GET /healthz HTTP/1.1\r\n\r\n").unwrap();
            let mut resp = String::new();
            r3.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"), "healthz at cap: {resp}");
            assert!(resp.contains("\"at_capacity\":true"));
            // /metrics must also be answered at the cap (a saturated
            // server is exactly when scrapes matter)
            let (mut r4, mut w4) = connect(addr);
            write!(w4, "GET /metrics HTTP/1.1\r\n\r\n").unwrap();
            let mut resp = String::new();
            r4.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"), "metrics at cap: {resp}");
            assert!(resp.contains("text/plain; version=0.0.4"));
            assert!(resp.contains("alps_net_connections_total"), "{resp}");
            send(&mut w1, "run");
            assert!(recv(&mut r1).starts_with("ok "));
            send(&mut w1, "shutdown");
            assert_eq!(recv(&mut r1), "ok shutdown");
            server.join().unwrap();
        });
    }

    #[test]
    fn eof_flushes_outstanding_requests() {
        // `printf '1 2 3\n' | nc host port` (no trailing blank line) must
        // still get its results: EOF is an implicit flush
        let m = random_model(44);
        let e = Engine::dense(&m).unwrap();
        let params = SamplingParams { max_new_tokens: 4, ..Default::default() };
        let cfg = TcpConfig::default();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(listener, &e, &params, &cfg).unwrap());
            let (mut r, mut w) = connect(addr);
            send(&mut w, "1 2 3");
            assert!(recv(&mut r).starts_with("queued "));
            w.shutdown(std::net::Shutdown::Write).unwrap(); // half-close = EOF
            let l = recv(&mut r);
            assert!(l.starts_with("ok "), "EOF flush got: {l}");
            let (mut r2, mut w2) = connect(addr);
            send(&mut w2, "shutdown");
            assert_eq!(recv(&mut r2), "ok shutdown");
            server.join().unwrap();
        });
    }

    #[test]
    fn protocol_errors_and_stats() {
        let m = random_model(43);
        let e = Engine::dense(&m).unwrap();
        let params = SamplingParams { max_new_tokens: 3, ..Default::default() };
        let cfg = TcpConfig::default();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(listener, &e, &params, &cfg).unwrap());
            let (mut r, mut w) = connect(addr);
            send(&mut w, "run"); // nothing queued
            assert_eq!(recv(&mut r), "err - no pending requests");
            send(&mut w, "not a prompt");
            assert!(recv(&mut r).starts_with("err - "));
            send(&mut w, "999"); // out of vocab: rejected at prefill
            assert!(recv(&mut r).starts_with("queued "));
            send(&mut w, "run");
            assert!(recv(&mut r).starts_with("err "));
            send(&mut w, "stats");
            assert!(recv(&mut r).starts_with("ok - "));
            send(&mut w, "shutdown");
            assert_eq!(recv(&mut r), "ok shutdown");
            server.join().unwrap();
        });
    }
}
