//! Generation engine: sampling + a dense / CSR / packed-N:M / int8
//! decode backend behind one type, so the batcher and CLI never care
//! which weight format serves. Construction registers the
//! `alps_serve_backend_layers` / `alps_serve_weight_bytes` gauges
//! (labelled `format=dense|csr|nm|int8`) so scrapes show what backend
//! is live and what its prunable weights cost.

use crate::model::{DecodeOps, Decoder, DenseOps, Model, SparseModel};
use crate::sparse::{Int8Model, NmModel};
use crate::util::{Rng, Timer};
use anyhow::Result;

/// Boxed-backend decoder: the single concrete decoder type the serve
/// stack works with (dense and CSR backends both erase to this). The
/// backend is `Send + Sync` so one engine can be shared by reference
/// across the TCP server's connection and scheduler threads.
pub type DynDecoder<'m> = Decoder<'m, Box<dyn DecodeOps + Send + Sync + 'm>>;

/// Per-request sampling configuration.
#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// Stop after this many generated tokens (at least 1 is produced).
    pub max_new_tokens: usize,
    /// 0.0 => greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits (0 => full vocab).
    pub top_k: usize,
    /// Generation stops after emitting this token, if set.
    pub stop_token: Option<u16>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { max_new_tokens: 32, temperature: 0.0, top_k: 0, stop_token: None }
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Sample a token id from a logits row under `params` — greedy when
/// temperature is 0, else temperature-scaled softmax (optionally top-k
/// truncated) driven by the deterministic `rng`.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u16 {
    if params.temperature <= 0.0 {
        return argmax(logits) as u16;
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        idx.sort_by(|&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(params.top_k);
    }
    let t = params.temperature as f64;
    let max = idx.iter().map(|&i| logits[i] as f64).fold(f64::NEG_INFINITY, f64::max);
    let weights: Vec<f64> = idx.iter().map(|&i| (((logits[i] as f64) - max) / t).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        u -= w;
        if u <= 0.0 {
            return i as u16;
        }
    }
    // float-rounding fallthrough (u can stay epsilon-positive after the
    // last weight): take the least-likely candidate. `idx` is empty only
    // for an empty logits row — a malformed model must yield a token id,
    // not abort the serving process
    idx.last().map_or(0, |&i| i as u16)
}

/// One completed generation (single-request path).
#[derive(Clone, Debug)]
pub struct Generation {
    pub tokens: Vec<u16>,
    /// Seconds spent consuming the prompt.
    pub prefill_secs: f64,
    /// End-to-end seconds including prefill.
    pub total_secs: f64,
}

/// Record which weight format an engine serves and what it costs: one
/// `{format=...}` series per backend, set at construction. A scrape of
/// any serving process shows the live backend and its prunable-weight
/// footprint next to the `alps_serve_*` traffic counters.
fn set_format_gauges(format: &'static str, layers: usize, weight_bytes: usize) {
    let r = crate::obs::global();
    r.gauge(
        "alps_serve_backend_layers",
        "prunable layers held by the serving weight backend",
        &[("format", format)],
    )
    .set(layers as f64);
    r.gauge(
        "alps_serve_weight_bytes",
        "prunable-weight footprint of the serving weight backend",
        &[("format", format)],
    )
    .set(weight_bytes as f64);
}

/// Generation engine over one model with a fixed weight backend.
pub struct Engine<'m> {
    decoder: DynDecoder<'m>,
    label: String,
}

impl<'m> Engine<'m> {
    /// Serve from dense weights (pre-resolved once, no per-step clones).
    pub fn dense(model: &'m Model) -> Result<Engine<'m>> {
        let names = model.prunable_names();
        let bytes = names
            .iter()
            .map(|n| model.weights.get(n).map(|t| t.numel() * 4).unwrap_or(0))
            .sum();
        set_format_gauges("dense", names.len(), bytes);
        let ops: Box<dyn DecodeOps + Send + Sync + 'm> = Box::new(DenseOps::new(model)?);
        Ok(Engine { decoder: Decoder::new(model, ops)?, label: "dense".to_string() })
    }

    /// Serve from CSR-converted prunable weights — the pruned-deployment
    /// path; beats dense once density drops below the CSR overhead.
    pub fn sparse(model: &'m Model) -> Result<Engine<'m>> {
        let sm = SparseModel::from_model(model)?;
        let label = format!("sparse(d={:.2})", sm.density());
        set_format_gauges("csr", model.prunable_names().len(), sm.bytes_sparse_vs_dense().0);
        let ops: Box<dyn DecodeOps + Send + Sync + 'm> = Box::new(sm);
        Ok(Engine { decoder: Decoder::new(model, ops)?, label })
    }

    /// Serve from packed N:M prunable weights ([`crate::sparse`]) — the
    /// semi-structured deployment path for what `--sparsity N:M` prunes.
    /// Layers that are not N:M-conformant fall back to CSR per layer
    /// (the label reports the split), so mixed checkpoints serve; packed
    /// layers decode bit-identically to the CSR backend.
    pub fn nm(model: &'m Model, n: usize, m: usize) -> Result<Engine<'m>> {
        let nm = NmModel::from_model(model, n, m)?;
        let label = format!("nm({n}:{m}, {}/{} packed)", nm.packed_layers(), nm.layer_count());
        set_format_gauges("nm", nm.layer_count(), nm.bytes_packed_vs_dense().0);
        let ops: Box<dyn DecodeOps + Send + Sync + 'm> = Box::new(nm);
        Ok(Engine { decoder: Decoder::new(model, ops)?, label })
    }

    /// Serve from int8-quantized prunable weights ([`crate::sparse`]) —
    /// the weight-bandwidth deployment path. Every prunable matrix is
    /// quantized at load (codes + per-column scales, ~25% of dense f32
    /// bytes); a `prune_quantize`-produced checkpoint recovers its codes
    /// exactly and its scales to ≤1 ulp, so decode matches dense to ulp
    /// precision and greedy token streams agree (see
    /// [`crate::sparse::int8`] for the exactness boundary).
    pub fn int8(model: &'m Model) -> Result<Engine<'m>> {
        let im = Int8Model::from_model(model)?;
        let (qb, db) = im.bytes_int8_vs_dense();
        let pct = if db == 0 { 0.0 } else { 100.0 * qb as f64 / db as f64 };
        let label = format!("int8({} layers, {pct:.1}% of dense bytes)", im.layer_count());
        set_format_gauges("int8", im.layer_count(), qb);
        let ops: Box<dyn DecodeOps + Send + Sync + 'm> = Box::new(im);
        Ok(Engine { decoder: Decoder::new(model, ops)?, label })
    }

    pub fn decoder(&self) -> &DynDecoder<'m> {
        &self.decoder
    }

    pub fn model(&self) -> &'m Model {
        self.decoder.model()
    }

    /// Backend description for logs/benches ("dense" / "sparse(d=0.30)").
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Single-request generation: batched prefill of the prompt (one
    /// multi-row pass per layer), then sample/decode until
    /// `max_new_tokens`, the stop token, or a full context window.
    pub fn generate(
        &self,
        prompt: &[u16],
        params: &SamplingParams,
        seed: u64,
    ) -> Result<Generation> {
        let timer = Timer::start();
        let mut cache = self.decoder.new_cache();
        let mut rng = Rng::new(seed);
        let mut logits = self.decoder.prefill_batch(&mut cache, prompt)?;
        let prefill_secs = timer.elapsed_secs();
        let mut tokens = Vec::new();
        loop {
            let tok = sample_token(&logits, params, &mut rng);
            tokens.push(tok);
            if tokens.len() >= params.max_new_tokens.max(1)
                || params.stop_token == Some(tok)
                || cache.len() >= self.model().cfg.seq_len
            {
                break;
            }
            logits = self.decoder.step(&mut cache, tok)?;
        }
        Ok(Generation { tokens, prefill_secs, total_secs: timer.elapsed_secs() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = random_model(20);
        let e = Engine::dense(&m).unwrap();
        let p = SamplingParams { max_new_tokens: 6, ..Default::default() };
        let a = e.generate(&[1, 2, 3], &p, 0).unwrap();
        let b = e.generate(&[1, 2, 3], &p, 99).unwrap(); // seed irrelevant for greedy
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 6);
        assert!(a.total_secs >= a.prefill_secs);
    }

    #[test]
    fn sparse_engine_matches_dense_greedy() {
        let mut m = random_model(21);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let pruned = crate::pruning::projection::topk_project(&w, w.data.len() / 2);
            m.weights.set_matrix(&name, &pruned).unwrap();
        }
        let de = Engine::dense(&m).unwrap();
        let se = Engine::sparse(&m).unwrap();
        assert!(se.label().starts_with("sparse"));
        let p = SamplingParams { max_new_tokens: 5, ..Default::default() };
        let a = de.generate(&[4, 2], &p, 0).unwrap();
        let b = se.generate(&[4, 2], &p, 0).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn nm_engine_matches_csr_engine_bitwise() {
        let mut m = random_model(25);
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let nm = crate::pruning::projection::nm_project(&w, 2, 4);
            m.weights.set_matrix(&name, &nm).unwrap();
        }
        let ce = Engine::sparse(&m).unwrap();
        let ne = Engine::nm(&m, 2, 4).unwrap();
        // 2 blocks x 6 prunable layers, all 2:4-conformant
        assert_eq!(ne.label(), "nm(2:4, 12/12 packed)");
        let p = SamplingParams { max_new_tokens: 6, ..Default::default() };
        let a = ce.generate(&[4, 2, 9], &p, 0).unwrap();
        let b = ne.generate(&[4, 2, 9], &p, 0).unwrap();
        assert_eq!(a.tokens, b.tokens);
        // dense agrees greedily too (float-tolerant path, same argmax)
        let de = Engine::dense(&m).unwrap();
        assert_eq!(de.generate(&[4, 2, 9], &p, 0).unwrap().tokens, b.tokens);
    }

    #[test]
    fn int8_engine_matches_dense_greedy_on_grid_checkpoint() {
        let mut m = random_model(26);
        // put every prunable weight on the int8 grid, as prune_quantize
        // checkpoints are: load-time requantization recovers the codes
        // exactly and the scales to <=1 ulp, so greedy tokens agree
        // (bitwise logit equality needs power-of-two scales — covered in
        // sparse::int8's tests)
        for name in m.prunable_names() {
            let w = m.weights.matrix(&name).unwrap();
            let q = crate::pruning::quantize::QuantizedWeights::quantize(&w);
            m.weights.set_matrix(&name, &q.dequantize()).unwrap();
        }
        let de = Engine::dense(&m).unwrap();
        let qe = Engine::int8(&m).unwrap();
        assert!(qe.label().starts_with("int8("), "label: {}", qe.label());
        let p = SamplingParams { max_new_tokens: 6, ..Default::default() };
        let a = de.generate(&[3, 1, 4], &p, 0).unwrap();
        let b = qe.generate(&[3, 1, 4], &p, 0).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn generation_respects_stop_and_context() {
        let m = random_model(22);
        let e = Engine::dense(&m).unwrap();
        // context window is 12: prompt (3 slots) + 9 decode steps fill the
        // cache, and the final sample costs no slot => exactly 10 tokens
        let p = SamplingParams { max_new_tokens: 100, ..Default::default() };
        let g = e.generate(&[1, 2, 3], &p, 0).unwrap();
        assert_eq!(g.tokens.len(), 10, "generated {} tokens", g.tokens.len());
        // stop token: first greedy token repeated as stop must stop at 1
        let stop = g.tokens[0];
        let p = SamplingParams {
            max_new_tokens: 100,
            stop_token: Some(stop),
            ..Default::default()
        };
        let g2 = e.generate(&[1, 2, 3], &p, 0).unwrap();
        assert_eq!(g2.tokens, vec![stop]);
    }

    #[test]
    fn temperature_sampling_in_vocab_and_seeded() {
        let m = random_model(23);
        let e = Engine::dense(&m).unwrap();
        let p = SamplingParams {
            max_new_tokens: 8,
            temperature: 1.0,
            top_k: 5,
            ..Default::default()
        };
        let a = e.generate(&[1], &p, 7).unwrap();
        let b = e.generate(&[1], &p, 7).unwrap();
        assert_eq!(a.tokens, b.tokens); // same seed, same stream
        for &t in &a.tokens {
            assert!((t as usize) < m.cfg.vocab);
        }
    }

    #[test]
    fn sample_token_greedy_and_topk() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.5];
        let mut rng = Rng::new(0);
        let p = SamplingParams::default();
        assert_eq!(sample_token(&logits, &p, &mut rng), 1);
        let p = SamplingParams { temperature: 0.5, top_k: 2, ..Default::default() };
        for _ in 0..50 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(t == 1 || t == 3, "top-2 violated: {t}");
        }
    }

    // regression: an empty logits row with temperature sampling used to
    // panic on the fallthrough (`idx.last().unwrap()`), aborting the
    // serving process on a malformed model instead of degrading
    #[test]
    fn sample_token_empty_logits_does_not_panic() {
        let mut rng = Rng::new(7);
        let p = SamplingParams { temperature: 0.8, ..Default::default() };
        assert_eq!(sample_token(&[], &p, &mut rng), 0);
        let p = SamplingParams { temperature: 0.8, top_k: 2, ..Default::default() };
        assert_eq!(sample_token(&[], &p, &mut rng), 0);
    }
}
