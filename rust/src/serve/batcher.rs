//! Continuous-batching request scheduler: a FIFO queue feeding a bounded
//! decode batch. Between decode steps, finished sequences are evicted and
//! waiting requests admitted (prefill happens at admission), so short and
//! long generations share the batch without head-of-line blocking.

use super::engine::{sample_token, Engine, SamplingParams};
use super::metrics::ServeMetrics;
use crate::model::KvCache;
use crate::util::{Rng, Timer};
use anyhow::Result;
use std::collections::VecDeque;

/// A queued generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub params: SamplingParams,
    /// Seed for this request's sampling stream.
    pub seed: u64,
}

/// A completed (or failed) generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub prompt_len: usize,
    /// End-to-end seconds from submission (queue wait included).
    pub total_secs: f64,
    /// Set when the request was rejected (bad prompt); `tokens` is empty.
    pub error: Option<String>,
}

/// One in-flight sequence.
struct SeqState {
    id: u64,
    cache: KvCache,
    /// Last sampled token — the input of the next decode step.
    next: u16,
    out: Vec<u16>,
    prompt_len: usize,
    params: SamplingParams,
    rng: Rng,
    /// Started at submission: measures queue wait + prefill + decode.
    timer: Timer,
}

/// FIFO continuous batcher over one [`Engine`].
pub struct Batcher<'e, 'm> {
    engine: &'e Engine<'m>,
    queue: VecDeque<(Request, Timer)>,
    active: Vec<SeqState>,
    max_batch: usize,
    next_id: u64,
    pub metrics: ServeMetrics,
}

impl<'e, 'm> Batcher<'e, 'm> {
    pub fn new(engine: &'e Engine<'m>, max_batch: usize) -> Batcher<'e, 'm> {
        Batcher {
            engine,
            queue: VecDeque::new(),
            active: Vec::new(),
            max_batch: max_batch.max(1),
            next_id: 0,
            metrics: ServeMetrics::new(),
        }
    }

    /// Enqueue a prompt with an auto-assigned id (returned).
    pub fn submit(&mut self, prompt: Vec<u16>, params: SamplingParams) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let seed = 0x5EED ^ id;
        self.submit_request(Request { id, prompt, params, seed });
        id
    }

    /// Enqueue a fully-specified request (caller owns id uniqueness).
    pub fn submit_request(&mut self, req: Request) {
        self.next_id = self.next_id.max(req.id + 1);
        self.queue.push_back((req, Timer::start()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Ids currently being decoded, in admission order.
    pub fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|s| s.id).collect()
    }

    /// Nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Cancel a request whose client is gone: a queued request is dropped
    /// before admission, an active sequence is evicted mid-decode (its KV
    /// slot frees immediately instead of decoding to completion for
    /// nobody). No [`Response`] is produced. Returns whether the id was
    /// still in flight — `false` means it had already completed (or never
    /// existed) and there was nothing to cancel.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|(r, _)| r.id == id) {
            self.queue.remove(pos);
            self.metrics.record_cancelled();
            return true;
        }
        if let Some(pos) = self.active.iter().position(|s| s.id == id) {
            self.active.remove(pos);
            self.metrics.record_cancelled();
            return true;
        }
        false
    }

    fn seq_finished(&self, s: &SeqState) -> bool {
        // `out` can be empty for a sequence evicted before emitting any
        // token (max_new == 0, prefill rejection); an empty output never
        // matches the stop token rather than panicking on `last()`
        let stop_hit = match (s.params.stop_token, s.out.last()) {
            (Some(stop), Some(&last)) => stop == last,
            _ => false,
        };
        s.out.len() >= s.params.max_new_tokens.max(1)
            || stop_hit
            || s.cache.len() >= self.engine.model().cfg.seq_len
    }

    /// Admit queued requests while the batch has room. Prefill runs here
    /// (admission time) as one multi-row pass per layer
    /// ([`crate::model::Decoder::prefill_batch`]); rejected prompts
    /// complete immediately as errors, and `max_new_tokens == 0` requests
    /// complete immediately with empty output (nothing to decode).
    fn admit(&mut self, finished: &mut Vec<Response>) {
        while self.active.len() < self.max_batch {
            let Some((req, timer)) = self.queue.pop_front() else { break };
            if req.params.max_new_tokens == 0 {
                // nothing to decode, but validate the prompt exactly as
                // prefill would so both outcomes agree with max_new >= 1
                let error = self
                    .engine
                    .decoder()
                    .validate_prompt(0, &req.prompt)
                    .err()
                    .map(|e| e.to_string());
                if error.is_none() {
                    self.metrics.record_request(timer.elapsed_secs());
                }
                finished.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    prompt_len: req.prompt.len(),
                    total_secs: timer.elapsed_secs(),
                    error,
                });
                continue;
            }
            let mut cache = self.engine.decoder().new_cache();
            let prefill_timer = Timer::start();
            let logits = match self.engine.decoder().prefill_batch(&mut cache, &req.prompt) {
                Ok(l) => {
                    self.metrics.record_prefill(req.prompt.len(), prefill_timer.elapsed_secs());
                    l
                }
                Err(e) => {
                    finished.push(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        prompt_len: req.prompt.len(),
                        total_secs: timer.elapsed_secs(),
                        error: Some(e.to_string()),
                    });
                    continue;
                }
            };
            let mut rng = Rng::new(req.seed);
            let first = sample_token(&logits, &req.params, &mut rng);
            let s = SeqState {
                id: req.id,
                cache,
                next: first,
                out: vec![first],
                prompt_len: req.prompt.len(),
                params: req.params,
                rng,
                timer,
            };
            if self.seq_finished(&s) {
                self.metrics.record_request(s.timer.elapsed_secs());
                finished.push(Response {
                    id: s.id,
                    tokens: s.out,
                    prompt_len: s.prompt_len,
                    total_secs: s.timer.elapsed_secs(),
                    error: None,
                });
            } else {
                self.active.push(s);
            }
        }
    }

    /// One scheduler tick: admit, run one batched decode step, sample one
    /// token per sequence, evict finished sequences. Returns requests that
    /// completed during this tick.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut finished = Vec::new();
        self.admit(&mut finished);
        if self.active.is_empty() {
            return Ok(finished);
        }
        let toks: Vec<u16> = self.active.iter().map(|s| s.next).collect();
        let timer = Timer::start();
        let step_result = {
            let mut refs: Vec<&mut KvCache> =
                self.active.iter_mut().map(|s| &mut s.cache).collect();
            self.engine.decoder().step_batch(&mut refs, &toks)
        };
        let logits = match step_result {
            Ok(l) => l,
            Err(e) => {
                // a mid-layer failure leaves KV caches partially advanced
                // (see Decoder::step_batch docs) — the in-flight sequences
                // cannot be decoded further, so fail them explicitly
                // instead of continuing over poisoned caches
                for s in self.active.drain(..) {
                    finished.push(Response {
                        id: s.id,
                        tokens: Vec::new(),
                        prompt_len: s.prompt_len,
                        total_secs: s.timer.elapsed_secs(),
                        error: Some(format!("decode failed: {e}")),
                    });
                }
                return Ok(finished);
            }
        };
        self.metrics.record_step(toks.len(), timer.elapsed_secs());
        for (i, s) in self.active.iter_mut().enumerate() {
            let tok = sample_token(logits.row(i), &s.params, &mut s.rng);
            s.out.push(tok);
            s.next = tok;
        }
        // evict finished sequences, preserving admission order of survivors
        let mut i = 0;
        while i < self.active.len() {
            if self.seq_finished(&self.active[i]) {
                let s = self.active.remove(i);
                self.metrics.record_request(s.timer.elapsed_secs());
                finished.push(Response {
                    id: s.id,
                    tokens: s.out,
                    prompt_len: s.prompt_len,
                    total_secs: s.timer.elapsed_secs(),
                    error: None,
                });
            } else {
                i += 1;
            }
        }
        Ok(finished)
    }

    /// Drain the queue and all in-flight sequences; returns all responses
    /// in completion order.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;

    fn params(n: usize) -> SamplingParams {
        SamplingParams { max_new_tokens: n, ..Default::default() }
    }

    #[test]
    fn admit_evict_ordering_under_full_queue() {
        // max_batch=2, four queued requests: 0 and 1 admitted first (FIFO);
        // 0 is short, so 2 is admitted the step after 0 finishes, then 3.
        let m = random_model(30);
        let e = Engine::dense(&m).unwrap();
        let mut b = Batcher::new(&e, 2);
        b.submit(vec![1, 2], params(2)); // id 0: finishes on 1st decode step
        b.submit(vec![3, 4], params(5)); // id 1
        b.submit(vec![5, 6], params(3)); // id 2: waits for a slot
        b.submit(vec![7], params(2)); // id 3: waits behind 2
        assert_eq!(b.pending(), 4);

        let done = b.step().unwrap(); // admits 0,1; decode finishes 0
        assert_eq!(done.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.active_ids(), vec![1]);
        assert_eq!(b.pending(), 2);

        let done = b.step().unwrap(); // admits 2 into the free slot
        assert!(done.is_empty());
        assert_eq!(b.active_ids(), vec![1, 2]);

        let mut all: Vec<u64> = done.iter().map(|r| r.id).collect();
        while !b.is_idle() {
            all.extend(b.step().unwrap().iter().map(|r| r.id));
        }
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3]);
        assert_eq!(b.metrics.requests_completed(), 4);
        // batch never exceeded the cap
        assert!(b.metrics.mean_batch() <= 2.0);
    }

    #[test]
    fn responses_match_unbatched_engine() {
        // batched scheduling must not change greedy outputs
        let m = random_model(31);
        let e = Engine::dense(&m).unwrap();
        let prompts: Vec<Vec<u16>> = vec![vec![1, 2, 3], vec![9, 8], vec![4], vec![6, 5, 7, 2]];
        let mut b = Batcher::new(&e, 3);
        for p in &prompts {
            b.submit(p.clone(), params(4));
        }
        let mut got = b.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), prompts.len());
        for (r, p) in got.iter().zip(&prompts) {
            assert!(r.error.is_none());
            let solo = e.generate(p, &params(4), 0).unwrap();
            assert_eq!(r.tokens, solo.tokens, "req {}", r.id);
            assert_eq!(r.prompt_len, p.len());
            assert!(r.total_secs >= 0.0);
        }
    }

    #[test]
    fn bad_prompt_rejected_without_poisoning_batch() {
        let m = random_model(32);
        let e = Engine::dense(&m).unwrap();
        let mut b = Batcher::new(&e, 2);
        b.submit(vec![], params(3)); // empty -> error
        b.submit(vec![200], params(3)); // out of vocab -> error
        b.submit(vec![1, 2], params(3)); // fine
        let mut got = b.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert!(got[0].error.is_some());
        assert!(got[1].error.is_some());
        assert!(got[2].error.is_none());
        assert_eq!(got[2].tokens.len(), 3);
    }

    #[test]
    fn zero_max_new_completes_empty_without_panic() {
        // regression: max_new == 0 used to leave an empty-output sequence
        // whose eviction check panicked on `out.last().expect(..)`
        let m = random_model(34);
        let e = Engine::dense(&m).unwrap();
        let mut b = Batcher::new(&e, 2);
        b.submit(vec![1, 2], params(0));
        b.submit(vec![3], params(2)); // normal request rides along
        b.submit(vec![200], params(0)); // invalid prompt must still error
        let mut got = b.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 3);
        assert!(got[0].tokens.is_empty());
        assert!(got[0].error.is_none());
        assert_eq!(got[1].tokens.len(), 2);
        // validation parity with the max_new >= 1 path: same rejection
        assert!(got[2].error.as_deref().unwrap_or("").contains("out of vocab"));
    }

    #[test]
    fn empty_output_sequence_never_matches_stop_token() {
        // regression: seq_finished panicked on an empty `out` when a stop
        // token was set; construct the state directly and probe it
        let m = random_model(35);
        let e = Engine::dense(&m).unwrap();
        let b = Batcher::new(&e, 1);
        let s = SeqState {
            id: 0,
            cache: e.decoder().new_cache(),
            next: 1,
            out: Vec::new(),
            prompt_len: 1,
            params: SamplingParams { stop_token: Some(1), ..Default::default() },
            rng: Rng::new(0),
            timer: Timer::start(),
        };
        assert!(!b.seq_finished(&s)); // must not panic, must not finish
    }

    #[test]
    fn batched_prefill_responses_match_unbatched_engine() {
        // admission prefill now runs multi-row; scheduling must still not
        // change greedy outputs vs the single-request path
        let m = random_model(36);
        let e = Engine::dense(&m).unwrap();
        let prompts: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7], vec![4]];
        let mut b = Batcher::new(&e, 2);
        for p in &prompts {
            b.submit(p.clone(), params(3));
        }
        let mut got = b.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        for (r, p) in got.iter().zip(&prompts) {
            let solo = e.generate(p, &params(3), 0).unwrap();
            assert_eq!(r.tokens, solo.tokens, "req {}", r.id);
        }
        assert_eq!(b.metrics.prompts_prefilled(), prompts.len());
    }

    #[test]
    fn cancel_evicts_queued_and_active_requests() {
        // client-disconnect eviction: a cancelled sequence stops decoding
        // (no response is ever produced for it), the batch slot frees for
        // waiting work, and survivors are unaffected
        let m = random_model(37);
        let e = Engine::dense(&m).unwrap();
        let mut b = Batcher::new(&e, 2);
        let id0 = b.submit(vec![1, 2], params(50)); // long generation
        let id1 = b.submit(vec![3, 4], params(3));
        let id2 = b.submit(vec![5], params(2)); // queued behind the cap
        b.step().unwrap(); // admits id0 + id1
        assert_eq!(b.active_ids(), vec![id0, id1]);

        // cancel the long-running active sequence and the queued one
        assert!(b.cancel(id0), "active sequence must be cancellable");
        assert!(b.cancel(id2), "queued request must be cancellable");
        assert_eq!(b.active_ids(), vec![id1]);
        assert_eq!(b.pending(), 0);

        let got = b.run_to_completion().unwrap();
        // only the surviving request completes; nothing stray from id0/id2
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![id1]);
        assert!(got[0].error.is_none());
        assert_eq!(b.metrics.requests_cancelled(), 2);
        // a finished (or unknown) id has nothing to cancel
        assert!(!b.cancel(id1));
        assert!(!b.cancel(999));
        assert_eq!(b.metrics.requests_cancelled(), 2);
    }

    #[test]
    fn single_token_requests_complete_at_admission() {
        let m = random_model(33);
        let e = Engine::dense(&m).unwrap();
        let mut b = Batcher::new(&e, 4);
        b.submit(vec![2, 3], params(1));
        let done = b.step().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 1);
        assert!(b.is_idle());
    }
}
