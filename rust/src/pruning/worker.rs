//! The pruning worker: hosts [`NativeEngine`] behind the binary frame
//! protocol (version 3) so a coordinator
//! ([`crate::coordinator::ShardedEngine`]) can fan layer solves across
//! machines.
//!
//! The worker is **stateless and method-agnostic**: every
//! [`wire::SolveRequest`] carries its own [`MethodSpec`]
//! (hyperparameters included) and sparsity target, so one worker pool
//! serves ALPS, SparseGPT, Wanda, … runs concurrently, and a worker that
//! restarts loses nothing but its in-flight solves (the coordinator's
//! owned-job pool requeues those).
//!
//! Since protocol v3 the fleet is **dynamic**: the coordinator keeps its
//! jobs in a long-lived owned pool rather than borrowing them into
//! per-block scoped threads, so membership can change mid-run. A worker
//! started with `--register host:port` dials the coordinator's
//! registration endpoint ([`register_with_coordinator`]), announces its
//! serve address in a [`wire::tag::REGISTER`] frame, and is acked with
//! the same frame echoed back; the coordinator then dials back like any
//! seed worker and starts handing it jobs. Nothing on the serve path
//! changes — a registered worker and a `--workers`-listed worker are
//! indistinguishable once joined, and departures (silence, disconnect,
//! refused redials) only cost a requeue of the jobs the member owned.
//!
//! Behaviours hosted here:
//!
//! * **Heartbeats** — while a solve runs, a sidecar thread writes a
//!   [`wire::tag::HEARTBEAT`] frame every
//!   [`WorkerConfig::heartbeat_every`] carrying the job id, the live ADMM
//!   iteration count (ALPS), and elapsed milliseconds. The coordinator
//!   uses missed beats to tell a dead worker from a slow solve (and to
//!   maintain a per-worker solve-time estimate that steers small layers
//!   toward slow members). Both threads share the socket through a
//!   mutex, so frames never interleave.
//! * **Worker-side gram** — a request whose calibration arrives as raw
//!   activations ([`wire::Calib::Activations`]) has its gram computed
//!   here with the same deterministic `linalg` kernels the coordinator
//!   uses, so results stay bit-identical while wide layers ship O(n·n_in)
//!   bytes instead of O(n_in^2).
//!
//! The worker port doubles as a monitoring endpoint: the first byte of a
//! connection is sniffed (frames open with the `b"AF"` magic, HTTP probes
//! with `G`), so `curl http://worker:7979/metrics` answers with the
//! process-global Prometheus page from [`crate::obs`] — including the
//! `alps_net_*` transport counters — and any other `GET` path with a
//! one-line health JSON. Probes work even over the connection cap (the
//! refusal path sniffs too), so a scrape never competes with coordinators
//! for solve slots.
//!
//! Connections come through the shared [`crate::net`] layer: the accept
//! loop, connection cap, and shutdown drain are [`NetServer`]'s; this
//! module only decodes [`tag::SOLVE`] frames, solves, and answers
//! [`tag::RESULT`] (or [`tag::ERROR`] with the job id when the solver
//! itself fails — a deterministic failure the coordinator must not
//! retry). Requests on one connection are processed in order; the
//! coordinator pipelines a bounded number of them to keep the worker
//! busy without unbounded buffering.
//!
//! CLI: `alps worker --addr 127.0.0.1:7979 [--max-conns 8]
//! [--max-frame-mb 1024] [--heartbeat-secs 2]
//! [--register COORD_HOST:PORT]`.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use super::engine::NativeEngine;
use super::wire::{self, tag};
use crate::net::framing::{read_frame, read_line_deadline, write_frame, FrameRead, LineRead};
use crate::net::server::{
    finish_refusal, request_path, respond_http, respond_http_json, write_http_response,
};
use crate::net::{lock, ConnHandler, NetServer, ServerConfig, READ_POLL, WRITE_TIMEOUT};
use anyhow::{Context as _, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How often the heartbeat thread wakes to check for work/shutdown —
/// bounds how long a finished solve waits for its sidecar to exit.
const HEARTBEAT_TICK: Duration = Duration::from_millis(20);

/// Longest accepted HTTP probe request line (frame-protocol traffic never
/// goes through the line reader).
const MAX_PROBE_LINE: usize = 4096;

/// How long an HTTP probe gets to deliver its request line before the
/// connection is dropped — probes must not pin worker slots.
const PROBE_DEADLINE: Duration = Duration::from_secs(10);

/// Worker endpoint configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Concurrent coordinator connections (each coordinator opens one).
    pub max_conns: usize,
    /// Largest accepted request frame in bytes (bounds a layer's
    /// weights + gram: ~1 GiB covers a 16k x 16k f32 gram).
    pub max_frame_bytes: usize,
    /// Interval between HEARTBEAT frames while a solve is in progress.
    /// Must sit well below the coordinator's heartbeat grace.
    pub heartbeat_every: Duration,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            max_conns: 8,
            max_frame_bytes: 1 << 30,
            heartbeat_every: Duration::from_secs(2),
        }
    }
}

/// A running worker endpoint. Construct, then [`Worker::serve`] on a
/// bound listener; call [`Worker::request_shutdown`] from another thread
/// (tests, signal handlers) to drain and stop.
pub struct Worker {
    net: NetServer,
    cfg: WorkerConfig,
    solved: AtomicUsize,
}

impl Worker {
    pub fn new(cfg: WorkerConfig) -> Worker {
        Worker {
            net: NetServer::new(ServerConfig {
                max_conns: cfg.max_conns,
                ..Default::default()
            }),
            cfg,
            solved: AtomicUsize::new(0),
        }
    }

    /// Layers solved over this worker's lifetime.
    pub fn layers_solved(&self) -> usize {
        self.solved.load(Ordering::SeqCst)
    }

    /// Coordinator connections accepted over this worker's lifetime —
    /// lets tests prove the persistent pool really reuses connections
    /// across block solves instead of redialing.
    pub fn connections_accepted(&self) -> usize {
        self.net.total_accepted()
    }

    /// Flag shutdown: in-flight solves finish and their results are
    /// delivered, then `serve` returns.
    pub fn request_shutdown(&self) {
        self.net.shutdown();
    }

    /// The flag [`Worker::request_shutdown`] sets — share it with sidecar
    /// threads (the `--register` dialer, signal handlers) so they stop
    /// when the worker drains.
    pub fn shutdown_flag(&self) -> &AtomicBool {
        self.net.shutdown_flag()
    }

    /// Serve solve requests until [`Worker::request_shutdown`]. Blocks.
    pub fn serve(&self, listener: TcpListener) -> Result<()> {
        self.net.run(listener, &WorkerHandler { worker: self })
    }
}

/// How long the `--register` dialer waits between attempts while the
/// coordinator's registration endpoint is unreachable (the worker may
/// legitimately come up first).
const REGISTER_RETRY: Duration = Duration::from_millis(500);

/// How long one registration attempt waits for the coordinator's ack
/// before the attempt is written off and retried.
const REGISTER_ACK_DEADLINE: Duration = Duration::from_secs(10);

/// Largest accepted ack frame — the ack is the REGISTER frame echoed
/// back, so it is as small as the address it carries.
const MAX_REGISTER_FRAME: usize = 4096;

/// Dial a running coordinator's registration endpoint (`prune --workers …
/// --register-addr`) and announce `advertise` as this worker's serve
/// address, retrying every [`REGISTER_RETRY`] until the coordinator
/// echoes the [`tag::REGISTER`] frame back as an ack or `shutdown` is
/// flagged. The coordinator dials the advertised address back exactly as
/// it dials seed workers, so `advertise` must be reachable from the
/// coordinator's side — pass the bound listener address, not `0.0.0.0`.
pub fn register_with_coordinator(
    coordinator: &str,
    advertise: &str,
    shutdown: &AtomicBool,
) -> Result<()> {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            anyhow::bail!("shutdown before registration was acknowledged");
        }
        match try_register(coordinator, advertise, shutdown) {
            Ok(()) => return Ok(()),
            Err(_) if !shutdown.load(Ordering::SeqCst) => std::thread::sleep(REGISTER_RETRY),
            Err(e) => return Err(e),
        }
    }
}

/// One registration attempt: connect, send REGISTER, require the echoed
/// ack. Any failure is retryable — the caller owns the retry loop.
fn try_register(coordinator: &str, advertise: &str, shutdown: &AtomicBool) -> Result<()> {
    let mut stream = TcpStream::connect(coordinator)
        .with_context(|| format!("dialing registration endpoint {coordinator}"))?;
    stream.set_read_timeout(Some(READ_POLL)).context("setting read timeout")?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
    write_frame(&mut stream, tag::REGISTER, &wire::encode_register(advertise))
        .context("sending REGISTER")?;
    match read_frame(
        &mut stream,
        MAX_REGISTER_FRAME,
        Some(shutdown),
        Some(REGISTER_ACK_DEADLINE),
    )? {
        FrameRead::Frame { tag: tag::REGISTER, payload } => {
            let echoed = wire::decode_register(&payload)?;
            if echoed != advertise {
                anyhow::bail!("coordinator acked a different address ({echoed})");
            }
            Ok(())
        }
        FrameRead::Frame { tag, .. } => {
            anyhow::bail!("unexpected registration ack tag {tag}")
        }
        FrameRead::Eof => anyhow::bail!("coordinator closed before acking registration"),
        FrameRead::Shutdown => {
            anyhow::bail!("shutdown before registration was acknowledged")
        }
    }
}

struct WorkerHandler<'w> {
    worker: &'w Worker,
}

impl ConnHandler for WorkerHandler<'_> {
    fn handle(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(READ_POLL)).context("setting read timeout")?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
        let _ = stream.set_nodelay(true);
        let mut reader = stream.try_clone().context("cloning stream")?;
        let shutdown = self.worker.net.shutdown_flag();
        // sniff the first byte before committing to the frame protocol:
        // frames open with the magic `b"AF"`, so a leading 'G' can only be
        // an HTTP `GET` probe (`/metrics` exposition or a health check)
        let first = loop {
            let mut b = [0u8; 1];
            match reader.peek(&mut b) {
                Ok(0) => return Ok(()),
                Ok(_) => break b[0],
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        };
        if first == b'G' {
            return answer_http_probe(reader, stream, shutdown, self.worker.layers_solved());
        }
        // the heartbeat sidecar and the request loop share the write side
        let writer = Mutex::new(stream);
        let max = self.worker.cfg.max_frame_bytes;
        loop {
            let (tag, payload) = match read_frame(&mut reader, max, Some(shutdown), None) {
                Ok(FrameRead::Frame { tag, payload }) => (tag, payload),
                Ok(FrameRead::Eof) | Ok(FrameRead::Shutdown) => return Ok(()),
                Err(e) => {
                    // an unreadable request (oversized frame, bad magic) is
                    // deterministic — tell the coordinator why before
                    // dropping the desynced connection, so its retry loop
                    // reports the real cause instead of a network fault
                    let _ = write_frame(
                        &mut *lock(&writer),
                        tag::ERROR,
                        &wire::encode_error(u64::MAX, &format!("request rejected: {e}")),
                    );
                    return Err(e);
                }
            };
            // protocol-level failures carry the u64::MAX sentinel, never a
            // real job id: the coordinator treats an ERROR for a job it
            // does not own as a transport fault (reroute), not a solver
            // verdict (abort)
            if tag != tag::SOLVE {
                write_frame(
                    &mut *lock(&writer),
                    tag::ERROR,
                    &wire::encode_error(u64::MAX, &format!("unexpected frame tag {tag}")),
                )?;
                continue;
            }
            let req = match wire::SolveRequest::decode(&payload) {
                Ok(r) => r,
                Err(e) => {
                    write_frame(
                        &mut *lock(&writer),
                        tag::ERROR,
                        &wire::encode_error(u64::MAX, &format!("bad solve request: {e}")),
                    )?;
                    continue;
                }
            };
            match solve_with_heartbeat(&req, &writer, self.worker.cfg.heartbeat_every) {
                Ok(resp) => {
                    self.worker.solved.fetch_add(1, Ordering::SeqCst);
                    write_frame(&mut *lock(&writer), tag::RESULT, &resp.encode())?;
                }
                Err(e) => write_frame(
                    &mut *lock(&writer),
                    tag::ERROR,
                    &wire::encode_error(req.job, &e.to_string()),
                )?,
            }
        }
    }

    /// Over-cap coordinators get a frame-level BUSY (retryable — the
    /// dispatcher backs off and reconnects; only solver failures abort a
    /// run), then a brief inbound drain so the reply isn't RST away.
    /// Over-cap `GET` probes are sniffed out first so monitoring stays
    /// live when every slot is grinding a solve.
    fn refuse(&self, stream: TcpStream, cap: usize) {
        let mut st = stream;
        let _ = st.set_read_timeout(Some(READ_POLL));
        let _ = st.set_write_timeout(Some(WRITE_TIMEOUT));
        let mut first = [0u8; 8];
        let have = std::io::Read::read(&mut st, &mut first).unwrap_or(0);
        if first[..have].starts_with(b"GET ") {
            let body = crate::obs::global().render();
            let _ = write_http_response(&mut st, crate::obs::prometheus::CONTENT_TYPE, &body);
        } else {
            let _ = write_frame(
                &mut st,
                tag::BUSY,
                &wire::encode_error(0, &format!("worker connection limit reached ({cap})")),
            );
        }
        finish_refusal(&st);
    }
}

/// Answer one HTTP probe on a worker connection: `/metrics` serves the
/// process-global Prometheus page, any other path a one-line health JSON.
/// One response per connection, then close — exactly the status-endpoint
/// contract, so a Prometheus scrape config can point at workers and the
/// coordinator uniformly.
fn answer_http_probe(
    reader: TcpStream,
    stream: TcpStream,
    shutdown: &AtomicBool,
    layers_solved: usize,
) -> Result<()> {
    let mut reader = BufReader::new(reader);
    let line = match read_line_deadline(&mut reader, MAX_PROBE_LINE, shutdown, PROBE_DEADLINE) {
        Ok(LineRead::Line(l)) => l,
        Ok(_) => return Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::TimedOut => return Ok(()),
        Err(e) => return Err(e.into()),
    };
    let mut stream = stream;
    if request_path(&line) == "/metrics" {
        let body = crate::obs::global().render();
        respond_http(
            &mut reader,
            &mut stream,
            MAX_PROBE_LINE,
            shutdown,
            crate::obs::prometheus::CONTENT_TYPE,
            &body,
        )?;
    } else {
        let body = format!("{{\"ok\":true,\"layers_solved\":{layers_solved}}}\n");
        respond_http_json(&mut reader, &mut stream, MAX_PROBE_LINE, shutdown, &body)?;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    Ok(())
}

/// Solve one request through the native engine — the exact code path a
/// local run takes, so results are bit-identical — while a sidecar thread
/// writes periodic HEARTBEAT frames so the coordinator can tell this
/// (possibly minutes-long) solve from a dead worker. The heartbeat covers
/// the whole span the coordinator is waiting on: problem rebuild
/// (including worker-side gram computation) plus the solve itself — and
/// deliberately does NOT watch the shutdown flag: a graceful drain
/// promises to finish and deliver in-flight solves, so the beats must
/// keep flowing until the solve is done or the coordinator would discard
/// the very result the drain guarantees.
fn solve_with_heartbeat(
    req: &wire::SolveRequest,
    writer: &Mutex<TcpStream>,
    every: Duration,
) -> Result<wire::SolveResponse> {
    let progress = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let started = Instant::now();
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut last_beat = Instant::now();
            while !done.load(Ordering::Relaxed) {
                std::thread::sleep(HEARTBEAT_TICK);
                if last_beat.elapsed() < every {
                    continue;
                }
                let beat = wire::encode_heartbeat(wire::Heartbeat {
                    job: req.job,
                    admm_iter: progress.load(Ordering::Relaxed),
                    elapsed_ms: started.elapsed().as_millis() as u64,
                });
                // write failures end the beats, not the solve: the request
                // loop will surface the broken socket when it answers
                if write_frame(&mut *lock(writer), tag::HEARTBEAT, &beat).is_err() {
                    return;
                }
                last_beat = Instant::now();
            }
        });
        let result = solve(req, &progress);
        // stop the sidecar before returning so the RESULT frame can never
        // race a final heartbeat (the scope join makes this a barrier)
        done.store(true, Ordering::Relaxed);
        result
    })
}

/// Rebuild the problem (computing the gram locally when the request
/// shipped activations) and solve it through [`NativeEngine`], storing
/// live ADMM progress into `progress` for the heartbeat sidecar.
fn solve(req: &wire::SolveRequest, progress: &AtomicU64) -> Result<wire::SolveResponse> {
    let problem = req.problem()?;
    let engine = NativeEngine::new(req.spec.clone());
    let res = engine.solve_layer_observed(&problem, req.target, Some(progress))?;
    Ok(wire::SolveResponse {
        job: req.job,
        secs: res.secs,
        admm_iters: res.admm_iters as u64,
        w: res.w,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityTarget;
    use crate::pruning::engine::Engine as _;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::MethodSpec;

    /// Send one request and collect frames until a RESULT/ERROR arrives,
    /// returning the response plus how many heartbeats preceded it.
    fn roundtrip_solve(
        stream: &mut TcpStream,
        req: &wire::SolveRequest,
    ) -> Result<(wire::SolveResponse, usize)> {
        write_frame(stream, tag::SOLVE, &req.encode())?;
        let mut beats = 0usize;
        loop {
            match read_frame(stream, 1 << 30, None, Some(Duration::from_secs(30)))? {
                FrameRead::Frame { tag: tag::RESULT, payload } => {
                    return Ok((wire::SolveResponse::decode(&payload)?, beats));
                }
                FrameRead::Frame { tag: tag::HEARTBEAT, payload } => {
                    let hb = wire::decode_heartbeat(&payload)?;
                    assert_eq!(hb.job, req.job, "heartbeat for the wrong job");
                    beats += 1;
                }
                FrameRead::Frame { tag: tag::ERROR, payload } => {
                    let (job, msg) = wire::decode_error(&payload)?;
                    anyhow::bail!("worker error on job {job}: {msg}")
                }
                _ => anyhow::bail!("unexpected reply"),
            }
        }
    }

    #[test]
    fn worker_solves_layers_bit_identically_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();

            let target = SparsityTarget::Unstructured(0.5);
            for (job, spec) in
                [MethodSpec::Magnitude, MethodSpec::Wanda].into_iter().enumerate()
            {
                let p = random_problem(12, 6, 50, job as u64);
                let req = wire::SolveRequest {
                    job: job as u64,
                    target,
                    spec: spec.clone(),
                    what: p.what.clone(),
                    calib: wire::Calib::Gram(p.h.clone()),
                };
                let (resp, _) = roundtrip_solve(&mut stream, &req).unwrap();
                assert_eq!(resp.job, job as u64);
                let local = NativeEngine::new(spec).solve_layer(&p, target).unwrap();
                assert_eq!(resp.w, local.w, "remote solve must be bit-identical");
            }
            assert_eq!(worker.layers_solved(), 2);

            // a deterministic solver failure comes back as a tagged error
            let p = random_problem(8, 4, 30, 7);
            let req = wire::SolveRequest {
                job: 9,
                target: SparsityTarget::NM { n: 2, m: 4 },
                spec: MethodSpec::AlpsStructured(Default::default()),
                what: p.what.clone(),
                calib: wire::Calib::Gram(p.h.clone()),
            };
            let err = roundtrip_solve(&mut stream, &req).unwrap_err().to_string();
            assert!(err.contains("job 9"), "{err}");

            drop(stream);
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn shipped_activations_solve_bit_identically() {
        // worker-side gram: the request carries X, the worker builds H
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();

            let target = SparsityTarget::Unstructured(0.6);
            let p = random_problem(14, 7, 9, 3); // 9 rows < 14 n_in: wide
            let x = p.x.as_deref().expect("random_problem attaches X").clone();
            let req = wire::SolveRequest {
                job: 1,
                target,
                spec: MethodSpec::Wanda,
                what: p.what.clone(),
                calib: wire::Calib::Activations(x),
            };
            let (resp, _) = roundtrip_solve(&mut stream, &req).unwrap();
            let local = NativeEngine::new(MethodSpec::Wanda)
                .solve_layer(&p, target)
                .unwrap();
            assert_eq!(resp.w, local.w, "worker-side gram must not change a bit");

            drop(stream);
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn worker_port_answers_http_probes() {
        use std::io::{Read as _, Write as _};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            // Prometheus scrape on the frame-protocol port
            let mut st = TcpStream::connect(addr).unwrap();
            st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            write!(st, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = String::new();
            st.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
            assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
            assert!(resp.contains("alps_net_connections_total"), "{resp}");
            // any other GET path gets the health line
            let mut st = TcpStream::connect(addr).unwrap();
            st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            write!(st, "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = String::new();
            st.read_to_string(&mut resp).unwrap();
            assert!(resp.contains("application/json"), "{resp}");
            assert!(resp.contains("\"ok\":true"), "{resp}");
            // probes must not disturb the frame protocol on the same port
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
            let p = random_problem(10, 5, 40, 1);
            let req = wire::SolveRequest {
                job: 1,
                target: SparsityTarget::Unstructured(0.5),
                spec: MethodSpec::Magnitude,
                what: p.what.clone(),
                calib: wire::Calib::Gram(p.h.clone()),
            };
            let (resp, _) = roundtrip_solve(&mut stream, &req).unwrap();
            assert_eq!(resp.job, 1);
            drop(stream);
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn register_dialer_respects_shutdown_and_requires_an_echoed_ack() {
        // a pre-set shutdown flag stops the retry loop before any dial
        let stop = AtomicBool::new(true);
        let err = register_with_coordinator("127.0.0.1:1", "w:1", &stop)
            .unwrap_err()
            .to_string();
        assert!(err.contains("shutdown before registration"), "{err}");

        // a faithful echo satisfies the dialer; the coordinator side here
        // is a hand-rolled one-shot acceptor standing in for
        // `ShardedEngine::listen_for_registrations`
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let reg = listener.local_addr().unwrap().to_string();
        let ack = std::thread::spawn(move || -> Result<String> {
            let (mut st, _) = listener.accept()?;
            st.set_read_timeout(Some(READ_POLL))?;
            let frame = read_frame(&mut st, 4096, None, Some(Duration::from_secs(10)))?;
            let FrameRead::Frame { tag: t, payload } = frame else {
                anyhow::bail!("no frame")
            };
            assert_eq!(t, tag::REGISTER);
            write_frame(&mut st, tag::REGISTER, &payload)?;
            wire::decode_register(&payload)
        });
        let stop = AtomicBool::new(false);
        register_with_coordinator(&reg, "worker-3:7979", &stop).unwrap();
        assert_eq!(ack.join().unwrap().unwrap(), "worker-3:7979");
    }

    #[test]
    fn long_solves_emit_heartbeats_with_progress() {
        // a worker configured with a (sub-tick) heartbeat interval beats
        // while solving; four back-to-back ALPS solves on 96-dim problems
        // give the sidecar a comfortably-long span to beat in
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = Worker::new(WorkerConfig {
            heartbeat_every: Duration::from_millis(1),
            ..Default::default()
        });
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();

            let mut total_beats = 0usize;
            for job in 0..4u64 {
                let p = random_problem(96, 48, 200, job);
                let req = wire::SolveRequest {
                    job,
                    target: SparsityTarget::Unstructured(0.7),
                    spec: MethodSpec::Alps(crate::config::AlpsConfig {
                        max_iters: 5000,
                        ..Default::default()
                    }),
                    what: p.what.clone(),
                    calib: wire::Calib::Gram(p.h.clone()),
                };
                let (resp, beats) = roundtrip_solve(&mut stream, &req).unwrap();
                assert!(resp.admm_iters > 0);
                total_beats += beats;
            }
            assert!(total_beats > 0, "no heartbeat across four ALPS solves");

            drop(stream);
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }
}
