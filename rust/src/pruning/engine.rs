//! Pluggable per-layer solve backends for the pruning pipeline.
//!
//! An [`Engine`] answers one question: *where* does a [`LayerProblem`] get
//! solved? [`NativeEngine`] runs the pure-rust methods and fans a block's
//! matrices across a scoped thread pool (the parallelism that used to live
//! inside the coordinator's scheduler); [`HloEngine`] routes ALPS through
//! the AOT HLO artifacts on the PJRT runtime, falling back to the native
//! solver for shapes without artifacts. Future backends (sharded across
//! machines, remote over TCP) implement the same trait and slot into
//! [`crate::pruning::session::PruneSession`] without touching the
//! pipeline.

use super::alps::Alps;
use super::{LayerProblem, MethodSpec};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::linalg::Matrix;
use crate::runtime::executor::AlpsHlo;
use crate::runtime::Runtime;
use crate::util::Timer;
use anyhow::Result;

/// One matrix to prune within a transformer block.
pub struct LayerJob {
    /// Weight tensor name (e.g. `blocks.0.attn.wq`).
    pub name: String,
    /// The layer-wise problem (weights + gram of this layer's inputs).
    pub problem: LayerProblem,
}

/// The solved layer: pruned weights plus solve diagnostics.
pub struct LayerResult {
    pub w: Matrix,
    /// Wall-clock seconds spent solving this layer.
    pub secs: f64,
    /// ADMM iterations (ALPS engines only, 0 otherwise).
    pub admm_iters: usize,
    /// Which worker solved it (`None` for in-process engines). Flows into
    /// [`super::session::ProgressEvent::LayerSolved`] so the status
    /// endpoint can attribute layers to pool members.
    pub worker: Option<String>,
}

/// A backend that solves layer-pruning problems.
pub trait Engine {
    /// Human-readable backend label for reports (e.g. `alps`, `alps(hlo)`).
    fn label(&self) -> String;

    /// Stable description of the engine's configuration. Recorded in
    /// checkpoints so a resume with different solver hyperparameters is
    /// rejected; the default suffices for config-free engines.
    fn config_digest(&self) -> String {
        self.label()
    }

    /// Solve one layer to the target sparsity.
    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult>;

    /// Solve all matrices of one block. The default runs sequentially
    /// (required for `!Send` backends like PJRT); engines with
    /// thread-safe solvers override this to parallelize.
    fn solve_block(
        &self,
        jobs: &[LayerJob],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        jobs.iter().map(|j| self.solve_layer(&j.problem, target)).collect()
    }

    /// Release any long-lived resources the engine holds across block
    /// solves (the sharded backend's persistent worker connections). The
    /// session calls this when a run finishes; in-process engines have
    /// nothing to release.
    fn close(&self) {}
}

/// Pure-rust engine: builds the method from a [`MethodSpec`] per worker
/// thread and fans a block's matrices across scoped threads.
pub struct NativeEngine {
    pub spec: MethodSpec,
}

impl NativeEngine {
    pub fn new(spec: MethodSpec) -> Self {
        NativeEngine { spec }
    }

    /// [`Engine::solve_layer`] with a live ADMM iteration counter (ALPS
    /// specs store their progress into it; other methods leave it at 0).
    /// The distributed worker reads the counter from its heartbeat
    /// thread; the solve itself is bit-identical with or without the
    /// observer.
    pub fn solve_layer_observed(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
        progress: Option<&std::sync::atomic::AtomicU64>,
    ) -> Result<LayerResult> {
        let timer = Timer::start();
        match &self.spec {
            // ALPS exposes its trace — keep the iteration count in reports
            MethodSpec::Alps(cfg) => {
                let (w, trace) = Alps::with_config(cfg.clone())
                    .prune_traced_observed(problem, target, progress)?;
                Ok(LayerResult {
                    w,
                    secs: timer.elapsed_secs(),
                    admm_iters: trace.admm_iters,
                    worker: None,
                })
            }
            spec => {
                let w = spec.prune(problem, target)?;
                Ok(LayerResult { w, secs: timer.elapsed_secs(), admm_iters: 0, worker: None })
            }
        }
    }
}

impl Engine for NativeEngine {
    fn label(&self) -> String {
        self.spec.label().to_string()
    }

    fn config_digest(&self) -> String {
        format!("{:?}", self.spec)
    }

    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult> {
        self.solve_layer_observed(problem, target, None)
    }

    fn solve_block(
        &self,
        jobs: &[LayerJob],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        // native methods hold no PJRT handles: parallelize across matrices
        std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|j| s.spawn(move || self.solve_layer(&j.problem, target)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("prune worker panicked"))
                .collect()
        })
    }
}

/// ALPS via the AOT HLO artifacts. Stays on the calling thread (PJRT
/// handles are `!Send`), so block solves are sequential; shapes without
/// artifacts fall back to the native ALPS solver with the same config.
pub struct HloEngine<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: AlpsConfig,
}

impl<'rt> HloEngine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: AlpsConfig) -> Self {
        HloEngine { rt, cfg }
    }
}

impl Engine for HloEngine<'_> {
    fn label(&self) -> String {
        "alps(hlo)".to_string()
    }

    fn config_digest(&self) -> String {
        format!("hlo {:?}", self.cfg)
    }

    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult> {
        let timer = Timer::start();
        let hlo = AlpsHlo { rt: self.rt, cfg: self.cfg.clone() };
        let (w, trace) = if hlo.supports(problem.n_in(), problem.n_out(), target) {
            hlo.prune_traced(problem, target)?
        } else {
            Alps::with_config(self.cfg.clone()).prune_traced(problem, target)?
        };
        Ok(LayerResult {
            w,
            secs: timer.elapsed_secs(),
            admm_iters: trace.admm_iters,
            worker: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::check_target;

    fn jobs(n: usize) -> Vec<LayerJob> {
        (0..n)
            .map(|i| LayerJob {
                name: format!("layer.{i}"),
                problem: random_problem(16, 8, 60, i as u64),
            })
            .collect()
    }

    #[test]
    fn native_engine_labels_match_spec() {
        for spec in MethodSpec::all() {
            assert_eq!(NativeEngine::new(spec.clone()).label(), spec.label());
        }
    }

    #[test]
    fn native_engine_solves_layer_to_target() {
        let p = random_problem(16, 8, 60, 0);
        let t = SparsityTarget::Unstructured(0.5);
        let eng = NativeEngine::new(MethodSpec::Magnitude);
        let r = eng.solve_layer(&p, t).unwrap();
        assert!(check_target(&r.w, t));
        assert!(r.secs >= 0.0);
        assert_eq!(r.admm_iters, 0);
    }

    #[test]
    fn observed_solve_is_bit_identical_and_reports_progress() {
        // the heartbeat progress counter must be a pure side channel
        use std::sync::atomic::{AtomicU64, Ordering};
        let p = random_problem(16, 8, 60, 3);
        let t = SparsityTarget::Unstructured(0.6);
        let eng = NativeEngine::new(MethodSpec::Alps(AlpsConfig::default()));
        let progress = AtomicU64::new(0);
        let observed = eng.solve_layer_observed(&p, t, Some(&progress)).unwrap();
        let plain = eng.solve_layer(&p, t).unwrap();
        assert_eq!(observed.w, plain.w, "observer must not perturb the solve");
        assert_eq!(progress.load(Ordering::Relaxed), observed.admm_iters as u64);
        assert!(observed.admm_iters > 0);
    }

    #[test]
    fn native_engine_alps_reports_admm_iters() {
        let p = random_problem(16, 8, 60, 1);
        let t = SparsityTarget::Unstructured(0.6);
        let eng = NativeEngine::new(MethodSpec::Alps(AlpsConfig::default()));
        let r = eng.solve_layer(&p, t).unwrap();
        assert!(r.admm_iters > 0, "ALPS trace must surface iterations");
        assert!(check_target(&r.w, t));
    }

    #[test]
    fn native_block_solve_matches_sequential() {
        // thread fan-out must be a pure parallelization: per-layer results
        // identical to solving each job alone, in job order
        let t = SparsityTarget::Unstructured(0.5);
        let eng = NativeEngine::new(MethodSpec::Wanda);
        let js = jobs(6);
        let par = eng.solve_block(&js, t).unwrap();
        assert_eq!(par.len(), 6);
        for (j, r) in js.iter().zip(&par) {
            let seq = eng.solve_layer(&j.problem, t).unwrap();
            assert_eq!(seq.w, r.w, "{}", j.name);
        }
    }

    #[test]
    fn engine_trait_is_object_safe_and_pluggable() {
        // a custom backend slots in through the same trait object the
        // session uses — this is the extension point the redesign is for
        struct ZeroEngine;
        impl Engine for ZeroEngine {
            fn label(&self) -> String {
                "zero".into()
            }
            fn solve_layer(
                &self,
                problem: &LayerProblem,
                _target: SparsityTarget,
            ) -> Result<LayerResult> {
                Ok(LayerResult {
                    w: Matrix::zeros(problem.n_in(), problem.n_out()),
                    secs: 0.0,
                    admm_iters: 0,
                    worker: None,
                })
            }
        }
        let eng: Box<dyn Engine> = Box::new(ZeroEngine);
        let js = jobs(2);
        let out = eng.solve_block(&js, SparsityTarget::Unstructured(0.9)).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].w.nnz(), 0);
        assert_eq!(eng.label(), "zero");
    }
}
