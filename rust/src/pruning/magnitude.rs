//! Magnitude Pruning (MP, Han et al. 2015): keep the k largest |W| entries.

use super::projection;
use super::{LayerProblem, PruneMethod};
use crate::config::SparsityTarget;
use crate::linalg::Matrix;
use anyhow::Result;

/// Global magnitude pruning — the classic baseline.
pub struct MagnitudePruning;

impl PruneMethod for MagnitudePruning {
    fn name(&self) -> &'static str {
        "mp"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        Ok(projection::project(&problem.what, target))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::check_target;

    #[test]
    fn respects_unstructured_budget() {
        let p = random_problem(16, 8, 64, 0);
        let t = SparsityTarget::Unstructured(0.7);
        let w = MagnitudePruning.prune(&p, t).unwrap();
        assert_eq!(w.nnz(), t.keep_count(16, 8));
        assert!(check_target(&w, t));
    }

    #[test]
    fn respects_nm_budget() {
        let p = random_problem(16, 8, 64, 1);
        let t = SparsityTarget::NM { n: 2, m: 4 };
        let w = MagnitudePruning.prune(&p, t).unwrap();
        assert!(check_target(&w, t));
    }

    #[test]
    fn kept_values_unchanged() {
        let p = random_problem(12, 6, 50, 2);
        let w = MagnitudePruning
            .prune(&p, SparsityTarget::Unstructured(0.5))
            .unwrap();
        for i in 0..w.data.len() {
            if w.data[i] != 0.0 {
                assert_eq!(w.data[i], p.what.data[i]);
            }
        }
    }

    #[test]
    fn error_increases_with_sparsity() {
        let p = random_problem(20, 10, 80, 3);
        let mut prev = 0.0;
        for s in [0.3, 0.5, 0.7, 0.9] {
            let w = MagnitudePruning
                .prune(&p, SparsityTarget::Unstructured(s))
                .unwrap();
            let e = p.rel_error(&w);
            assert!(e >= prev - 1e-9, "sparsity {s}: {e} < {prev}");
            prev = e;
        }
    }
}
