//! Post-pruning quantization — the second future-work axis the paper's
//! conclusion names ("extending ALPS to incorporate ... quantization").
//!
//! Symmetric per-output-channel int8 quantization of the *surviving*
//! weights, with an optional PCG-style re-fit: after rounding, the scales
//! are re-chosen to minimize the layer-wise reconstruction objective on
//! the frozen support + codes (a 1-D least squares per column, exact).
//!
//! The serving side of this format is [`crate::sparse::int8`]
//! (`alps serve --format int8`): it re-quantizes every prunable matrix
//! at load and decodes from the codes + scales directly. A checkpoint
//! whose weights already sit on the grid (quantize → dequantize, as
//! `examples/prune_quantize.rs` writes) re-quantizes with exact codes
//! and ≤1-ulp scales (f32 `(127*s)/127` is only an identity for special
//! scales, e.g. powers of two), so serving it under int8 matches dense
//! to ulp precision and greedy token streams agree.

use super::LayerProblem;
use crate::linalg::Matrix;

/// A quantized sparse matrix: int8 codes + per-column scales + support.
#[derive(Clone, Debug)]
pub struct QuantizedWeights {
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
}

impl QuantizedWeights {
    /// Symmetric per-column int8 quantization (scale = max|w| / 127).
    pub fn quantize(w: &Matrix) -> QuantizedWeights {
        let mut scales = vec![0.0f32; w.cols];
        for c in 0..w.cols {
            let maxabs = (0..w.rows)
                .map(|r| w.at(r, c).abs())
                .fold(0.0f32, f32::max);
            scales[c] = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
        }
        let mut codes = vec![0i8; w.rows * w.cols];
        for r in 0..w.rows {
            for c in 0..w.cols {
                let q = (w.at(r, c) / scales[c]).round();
                codes[r * w.cols + c] = q.clamp(-127.0, 127.0) as i8;
            }
        }
        QuantizedWeights { rows: w.rows, cols: w.cols, codes, scales }
    }

    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                m.data[r * self.cols + c] =
                    self.codes[r * self.cols + c] as f32 * self.scales[c];
            }
        }
        m
    }

    /// Re-fit the per-column scales against the layer objective: for fixed
    /// codes q_c, the optimal scale is argmin_s ||X what_c - s X q_c||^2
    /// = (q_c^T g_c) / (q_c^T H q_c) — exact 1-D least squares using the
    /// calibration gram (an ALPS-flavored touch no naive RTN quantizer has).
    pub fn refit_scales(&mut self, problem: &LayerProblem) {
        let h = &problem.h;
        let g = &problem.g;
        for c in 0..self.cols {
            let q: Vec<f32> = (0..self.rows)
                .map(|r| self.codes[r * self.cols + c] as f32)
                .collect();
            // qHq and qg
            let hq = crate::linalg::matmul::matvec(h, &q);
            let qhq: f64 = q.iter().zip(&hq).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let qg: f64 = (0..self.rows)
                .map(|r| q[r] as f64 * g.at(r, c) as f64)
                .sum();
            if qhq > 1e-12 {
                self.scales[c] = (qg / qhq) as f32;
            }
        }
    }

    /// Bits per weight counting only stored values (codes of the support
    /// + one f32 scale per column), the usual compression accounting.
    pub fn bits_per_weight(&self) -> f64 {
        let nnz = self.codes.iter().filter(|c| **c != 0).count();
        let bits = 8.0 * nnz as f64 + 32.0 * self.cols as f64;
        bits / (self.rows * self.cols) as f64
    }
}

/// Prune-then-quantize: quantize a pruned matrix and report the combined
/// reconstruction error before/after scale re-fitting.
pub fn prune_quantize_error(
    problem: &LayerProblem,
    pruned: &Matrix,
) -> (f64, f64, QuantizedWeights) {
    let mut q = QuantizedWeights::quantize(pruned);
    let err_rtn = problem.rel_error(&q.dequantize());
    q.refit_scales(problem);
    let err_refit = problem.rel_error(&q.dequantize());
    (err_rtn, err_refit, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityTarget;
    use crate::pruning::alps::Alps;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::PruneMethod;

    #[test]
    fn roundtrip_small_error() {
        let p = random_problem(16, 8, 60, 0);
        let q = QuantizedWeights::quantize(&p.what);
        let deq = q.dequantize();
        // int8 symmetric: max relative error per entry ~ 1/254 of col max
        let err = deq.sub(&p.what).fro_norm() / p.what.fro_norm();
        assert!(err < 0.01, "quant err {err}");
    }

    #[test]
    fn zeros_stay_zero() {
        let p = random_problem(16, 8, 60, 1);
        let pruned = Alps::default()
            .prune(&p, SparsityTarget::Unstructured(0.7))
            .unwrap();
        let q = QuantizedWeights::quantize(&pruned);
        let deq = q.dequantize();
        for i in 0..pruned.data.len() {
            if pruned.data[i] == 0.0 {
                assert_eq!(deq.data[i], 0.0);
            }
        }
    }

    #[test]
    fn refit_never_hurts() {
        let p = random_problem(20, 10, 80, 2);
        let pruned = Alps::default()
            .prune(&p, SparsityTarget::Unstructured(0.6))
            .unwrap();
        let (err_rtn, err_refit, _) = prune_quantize_error(&p, &pruned);
        assert!(err_refit <= err_rtn + 1e-9, "{err_refit} > {err_rtn}");
    }

    #[test]
    fn codes_in_range() {
        let p = random_problem(12, 6, 50, 3);
        let q = QuantizedWeights::quantize(&p.what);
        assert!(q.codes.iter().all(|c| (-127..=127).contains(&(*c as i32))));
    }

    #[test]
    fn bits_per_weight_drops_with_sparsity() {
        let p = random_problem(16, 8, 60, 4);
        let dense_q = QuantizedWeights::quantize(&p.what);
        let pruned = Alps::default()
            .prune(&p, SparsityTarget::Unstructured(0.8))
            .unwrap();
        let sparse_q = QuantizedWeights::quantize(&pruned);
        assert!(sparse_q.bits_per_weight() < dense_q.bits_per_weight());
        assert!(sparse_q.bits_per_weight() < 8.0);
    }

    #[test]
    fn scale_refit_uses_calibration() {
        // on an anisotropic problem, refit scales differ from RTN scales
        let p = random_problem(16, 4, 60, 5);
        let mut q = QuantizedWeights::quantize(&p.what);
        let before = q.scales.clone();
        q.refit_scales(&p);
        assert!(q.scales.iter().zip(&before).any(|(a, b)| (a - b).abs() > 1e-6));
    }
}
