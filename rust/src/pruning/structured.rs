//! Structured (input-neuron) pruning — the paper's stated future-work
//! extension ("Future work will consider extending ALPS to incorporate
//! structured pruning constraints").
//!
//! Here the ℓ0 constraint acts on *rows* of W (input neurons): at most
//! `k_rows` rows may be non-zero, which removes entire input channels and
//! needs no sparse hardware at all. The same operator-splitting template
//! applies — only the projection changes: P_k projects onto the best
//! `k_rows` rows by Euclidean row-norm of Z (the exact row-sparse
//! projection), and the PCG refinement runs on the row-support.

use super::{LayerProblem, PruneMethod};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::linalg::solve::pcg_support;
use crate::linalg::{Matrix, SymEig};
use crate::pruning::alps::{rho_update, DiagScaling};
use anyhow::Result;

/// Project onto matrices with at most `k_rows` non-zero rows (exact:
/// keep the rows with the largest L2 norms; ties to the lower index).
pub fn row_project(z: &Matrix, k_rows: usize) -> Matrix {
    let mut norms: Vec<(usize, f64)> = (0..z.rows)
        .map(|r| {
            let s: f64 = z.row(r).iter().map(|v| (*v as f64) * (*v as f64)).sum();
            (r, s)
        })
        .collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut out = Matrix::zeros(z.rows, z.cols);
    for &(r, _) in norms.iter().take(k_rows) {
        out.row_mut(r).copy_from_slice(z.row(r));
    }
    out
}

/// Row-structured magnitude baseline: keep the k_rows largest-norm rows
/// of What, scored by ||w_r|| * ||x_r|| (Wanda-style activation weighting).
pub fn structured_magnitude(problem: &LayerProblem, k_rows: usize) -> Matrix {
    let norms = problem.x_col_norms();
    let w = &problem.what;
    let mut scored: Vec<(usize, f64)> = (0..w.rows)
        .map(|r| {
            let s: f64 = w.row(r).iter().map(|v| (*v as f64) * (*v as f64)).sum();
            (r, s.sqrt() * norms[r] as f64)
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut out = Matrix::zeros(w.rows, w.cols);
    for &(r, _) in scored.iter().take(k_rows) {
        out.row_mut(r).copy_from_slice(w.row(r));
    }
    out
}

/// ALPS with a row-structured constraint.
pub struct StructuredAlps {
    pub cfg: AlpsConfig,
}

impl Default for StructuredAlps {
    fn default() -> Self {
        StructuredAlps { cfg: AlpsConfig::default() }
    }
}

impl StructuredAlps {
    /// Prune so that at most `(1 - sparsity) * n_in` input rows survive.
    pub fn prune_rows(&self, problem: &LayerProblem, sparsity: f64) -> Result<Matrix> {
        let cfg = &self.cfg;
        let n_in = problem.n_in();
        let n_out = problem.n_out();
        let k_rows = (((1.0 - sparsity) * n_in as f64).floor() as usize).max(1);

        let (scaling, hs) = DiagScaling::from_gram(&problem.h, cfg.damp);
        let gs = scaling.scale_g(&problem.g);
        let whats = scaling.to_scaled(&problem.what);
        let eig = SymEig::new(&hs)?;

        let mut d = whats.clone();
        let mut v = Matrix::zeros(n_in, n_out);
        let mut rho = cfg.rho0;
        let mut t = 0usize;
        let mut prev_supp = d.support_mask();
        // row-count budget expressed in weight units for the rho bands
        let k_weights = k_rows * n_out;
        while t < cfg.max_iters {
            for _ in 0..cfg.update_every {
                let mut b = gs.sub(&v);
                b.axpy(rho, &d);
                let w = eig.ridge_solve(rho, &b);
                let mut z = w.clone();
                z.axpy(1.0 / rho, &v);
                d = row_project(&z, k_rows);
                let mut wd = w.sub(&d);
                wd = wd.scale(rho);
                v = v.add(&wd);
                t += 1;
            }
            let supp = d.support_mask();
            let s_t = supp
                .data
                .iter()
                .zip(&prev_supp.data)
                .filter(|(a, b)| a != b)
                .count();
            prev_supp = supp;
            if s_t == 0 {
                break;
            }
            rho = rho_update(rho, s_t, k_weights, cfg);
        }

        let mask = d.support_mask();
        let (w_refined, _) = pcg_support(&hs, &gs, &d, &mask, cfg.pcg_iters, 1e-12);
        Ok(scaling.to_unscaled(&w_refined))
    }
}

/// Adapter so structured ALPS can ride the PruneMethod registry: the
/// SparsityTarget fraction is interpreted as a *row* fraction.
pub struct StructuredAlpsMethod(pub StructuredAlps);

impl PruneMethod for StructuredAlpsMethod {
    fn name(&self) -> &'static str {
        "alps-struct"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        match target {
            SparsityTarget::Unstructured(s) => self.0.prune_rows(problem, s),
            SparsityTarget::NM { .. } => {
                anyhow::bail!("structured ALPS does not support N:M targets")
            }
        }
    }
}

/// Count rows with any non-zero entry.
pub fn nonzero_rows(w: &Matrix) -> usize {
    (0..w.rows)
        .filter(|&r| w.row(r).iter().any(|v| *v != 0.0))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;

    #[test]
    fn row_project_exact_row_count() {
        let p = random_problem(12, 6, 50, 0);
        for k in [1usize, 4, 8, 12] {
            let out = row_project(&p.what, k);
            assert_eq!(nonzero_rows(&out), k);
        }
    }

    #[test]
    fn row_project_keeps_largest_rows() {
        let mut w = Matrix::zeros(3, 2);
        w.row_mut(0).copy_from_slice(&[0.1, 0.1]);
        w.row_mut(1).copy_from_slice(&[5.0, 5.0]);
        w.row_mut(2).copy_from_slice(&[1.0, 1.0]);
        let out = row_project(&w, 2);
        assert_eq!(out.row(0), &[0.0, 0.0]);
        assert_eq!(out.row(1), &[5.0, 5.0]);
        assert_eq!(out.row(2), &[1.0, 1.0]);
    }

    #[test]
    fn structured_alps_respects_row_budget() {
        let p = random_problem(20, 8, 80, 1);
        let w = StructuredAlps::default().prune_rows(&p, 0.5).unwrap();
        assert!(nonzero_rows(&w) <= 10);
    }

    #[test]
    fn structured_alps_beats_structured_magnitude() {
        let p = random_problem(24, 12, 100, 2);
        let sparsity = 0.5;
        let k_rows = 12;
        let w_alps = StructuredAlps::default().prune_rows(&p, sparsity).unwrap();
        let w_mag = structured_magnitude(&p, k_rows);
        assert!(
            p.rel_error(&w_alps) < p.rel_error(&w_mag),
            "alps-struct {} !< struct-mp {}",
            p.rel_error(&w_alps),
            p.rel_error(&w_mag)
        );
    }

    #[test]
    fn structured_is_harder_than_unstructured() {
        // at equal weight budget, a row constraint cannot do better
        let p = random_problem(20, 10, 80, 3);
        let s = 0.5;
        let w_struct = StructuredAlps::default().prune_rows(&p, s).unwrap();
        let w_free = crate::pruning::alps::Alps::default()
            .prune(&p, SparsityTarget::Unstructured(s))
            .unwrap();
        assert!(p.rel_error(&w_struct) >= p.rel_error(&w_free) * 0.99);
    }

    #[test]
    fn method_adapter_rejects_nm() {
        let p = random_problem(8, 4, 40, 4);
        let m = StructuredAlpsMethod(StructuredAlps::default());
        assert!(m.prune(&p, SparsityTarget::NM { n: 2, m: 4 }).is_err());
        assert!(m.prune(&p, SparsityTarget::Unstructured(0.5)).is_ok());
    }
}
