//! Live pruning progress over TCP: a [`StatusBoard`] accumulates the
//! session's [`ProgressEvent`] stream (including per-worker attribution
//! from a sharded run) and a [`StatusServer`] answers one-shot queries
//! with a JSON snapshot — the "surfacing `ProgressEvent`s on a TCP status
//! endpoint" follow-up from the PR 3 roadmap.
//!
//! Sharded runs additionally feed worker keepalives into the board
//! ([`StatusBoard::note_heartbeat`], wired up by
//! `ShardedEngine::set_status_board`): the snapshot's `heartbeats` map
//! counts beats per pool member and `solving` carries each member's live
//! in-solve progress (job, ADMM iteration, elapsed ms) — so an operator
//! can tell a worker grinding a long ALPS layer from one that died.
//!
//! With dynamic membership (protocol v3) the board also tracks the fleet
//! itself: [`StatusBoard::note_worker_joined`] /
//! [`StatusBoard::note_worker_left`] (wired to the dispatcher's
//! add/leave paths) maintain a live `fleet` size, a `fleet_series` of
//! `[elapsed_secs, size]` samples — fleet size over time — and a
//! `fleet_events` log of per-worker join/leave records, so an operator
//! can reconstruct exactly when capacity came and went.
//!
//! Wiring: pass `StatusBoard::observe` as (part of) the session observer
//! and serve the board on a listener; the CLI does exactly this for
//! `alps prune --status-addr 127.0.0.1:7878`:
//!
//! ```text
//! curl http://127.0.0.1:7878/status       # HTTP JSON snapshot
//! curl http://127.0.0.1:7878/metrics      # Prometheus text exposition
//! printf 'status\n' | nc 127.0.0.1 7878   # same JSON as one line
//! ```
//!
//! The endpoint is read-only and stateless per connection (one query, one
//! answer, close), served by the shared [`crate::net`] accept loop, so a
//! monitoring scrape can never interfere with the run it watches. Both
//! routes survive a full connection table: the refusal path sniffs the
//! first bytes and still answers `GET` probes.
//!
//! The snapshot carries wall-clock shape too — `elapsed_secs` (stamped on
//! every progress event by the session) and `block_secs` (per-block wall
//! time derived from consecutive `BlockStarted` stamps). Heartbeats
//! additionally publish the `alps_prune_admm_iteration{worker=...}` gauge
//! to the [`crate::obs`] registry, so `/metrics` shows live solver
//! progress next to the counters.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use super::session::{json_escape, ProgressEvent};
use super::wire::Heartbeat;
use crate::net::framing::{read_line_deadline, LineRead};
use crate::net::server::{
    finish_refusal, request_path, respond_http, respond_http_json, write_http_json,
    write_http_response,
};
use crate::net::{lock, ConnHandler, NetServer, ServerConfig, READ_POLL, WRITE_TIMEOUT};
use anyhow::{Context as _, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

/// Longest accepted query line (a status query is one short word; HTTP
/// request lines from probes stay well under this).
const MAX_QUERY_LINE: usize = 4096;

/// A connected client gets this long to send its query; a silent
/// connection is dropped so it cannot pin a handler slot for the whole
/// (possibly hours-long) pruning run.
const QUERY_DEADLINE: Duration = Duration::from_secs(10);

/// Attribution key for layers solved by the in-process engine.
const LOCAL_WORKER: &str = "local";

/// Snapshot of a pruning run as seen through its progress events.
#[derive(Clone, Default)]
pub struct StatusSnapshot {
    pub model: String,
    pub method: String,
    pub target: String,
    pub n_blocks: usize,
    /// Blocks fully finished (resumed blocks count).
    pub blocks_done: usize,
    pub layers_solved: usize,
    pub checkpoints_written: usize,
    pub last_layer: String,
    pub running: bool,
    pub finished: bool,
    pub total_secs: f64,
    /// Layers solved per pool member (`"local"` for in-process solves).
    pub workers: BTreeMap<String, usize>,
    /// Keepalive frames received per pool member while it was solving —
    /// a worker with a climbing beat count and a flat solve count is
    /// alive but grinding through a long ALPS layer (sharded runs only).
    pub heartbeats: BTreeMap<String, u64>,
    /// Latest in-solve progress per pool member:
    /// `(job, admm_iter, elapsed_ms)` from its most recent heartbeat.
    pub solving: BTreeMap<String, (u64, u64, u64)>,
    /// Live fleet size: members currently in the dispatcher pool
    /// (sharded runs with dynamic membership only).
    pub fleet: usize,
    /// Fleet size over time: one `(elapsed_secs, size)` sample per
    /// membership change, stamped with the newest progress-event clock.
    pub fleet_series: Vec<(f64, usize)>,
    /// Per-worker membership log: `(elapsed_secs, "join"|"leave",
    /// worker)` in arrival order.
    pub fleet_events: Vec<(f64, String, String)>,
    /// Wall seconds since the session started, as stamped on the most
    /// recent progress event — lets a scraper judge run age without
    /// clock agreement with the coordinator.
    pub elapsed_secs: f64,
    /// Wall seconds each finished block took, keyed by block index —
    /// derived from consecutive `BlockStarted` stamps (the final block
    /// closes on `RunFinished`'s total).
    pub block_secs: BTreeMap<usize, f64>,
    /// Bookkeeping for `block_secs`: the currently running block and its
    /// start stamp. Not rendered in the JSON snapshot.
    pub open_block: Option<(usize, f64)>,
}

impl StatusSnapshot {
    /// Render as a single JSON object (one line, newline-terminated).
    pub fn to_json(&self) -> String {
        let workers = self
            .workers
            .iter()
            .map(|(w, n)| format!("\"{}\":{}", json_escape(w), n))
            .collect::<Vec<_>>()
            .join(",");
        let heartbeats = self
            .heartbeats
            .iter()
            .map(|(w, n)| format!("\"{}\":{}", json_escape(w), n))
            .collect::<Vec<_>>()
            .join(",");
        let solving = self
            .solving
            .iter()
            .map(|(w, (job, iter, ms))| {
                format!(
                    "\"{}\":{{\"job\":{job},\"admm_iter\":{iter},\"elapsed_ms\":{ms}}}",
                    json_escape(w)
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        let fin = |v: f64| if v.is_finite() { v } else { 0.0 };
        let block_secs = self
            .block_secs
            .iter()
            .map(|(b, s)| format!("\"{b}\":{}", fin(*s)))
            .collect::<Vec<_>>()
            .join(",");
        let fleet_series = self
            .fleet_series
            .iter()
            .map(|(t, n)| format!("[{},{n}]", fin(*t)))
            .collect::<Vec<_>>()
            .join(",");
        let fleet_events = self
            .fleet_events
            .iter()
            .map(|(t, ev, w)| {
                format!(
                    "{{\"at\":{},\"event\":\"{}\",\"worker\":\"{}\"}}",
                    fin(*t),
                    json_escape(ev),
                    json_escape(w)
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"model\":\"{}\",\"method\":\"{}\",\"target\":\"{}\",\
             \"n_blocks\":{},\"blocks_done\":{},\"layers_solved\":{},\
             \"checkpoints_written\":{},\"last_layer\":\"{}\",\
             \"running\":{},\"finished\":{},\"total_secs\":{},\
             \"elapsed_secs\":{},\"block_secs\":{{{}}},\
             \"workers\":{{{}}},\"heartbeats\":{{{}}},\"solving\":{{{}}},\
             \"fleet\":{},\"fleet_series\":[{}],\"fleet_events\":[{}]}}\n",
            json_escape(&self.model),
            json_escape(&self.method),
            json_escape(&self.target),
            self.n_blocks,
            self.blocks_done,
            self.layers_solved,
            self.checkpoints_written,
            json_escape(&self.last_layer),
            self.running,
            self.finished,
            fin(self.total_secs),
            fin(self.elapsed_secs),
            block_secs,
            workers,
            heartbeats,
            solving,
            self.fleet,
            fleet_series,
            fleet_events,
        )
    }
}

/// Shared accumulator between the session observer and the status server.
#[derive(Default)]
pub struct StatusBoard {
    state: Mutex<StatusSnapshot>,
}

impl StatusBoard {
    pub fn new() -> StatusBoard {
        StatusBoard::default()
    }

    /// Fold one progress event into the snapshot. Designed to be called
    /// from a [`super::session::PruneSession`] observer closure.
    pub fn observe(&self, ev: &ProgressEvent) {
        let mut st = lock(&self.state);
        match ev {
            ProgressEvent::RunStarted { model, method, target, n_blocks } => {
                // membership is pool state, not run state: a worker that
                // registered while the model was still loading must not
                // be erased by the run-start reset
                *st = StatusSnapshot {
                    model: model.clone(),
                    method: method.clone(),
                    target: target.clone(),
                    n_blocks: *n_blocks,
                    running: true,
                    fleet: st.fleet,
                    fleet_series: std::mem::take(&mut st.fleet_series),
                    fleet_events: std::mem::take(&mut st.fleet_events),
                    ..Default::default()
                };
            }
            ProgressEvent::BlockResumed { elapsed_secs, .. } => {
                st.blocks_done += 1;
                st.elapsed_secs = st.elapsed_secs.max(*elapsed_secs);
            }
            // starting block k means blocks 0..k are finished — this is
            // what keeps `blocks_done` moving on runs without
            // `--checkpoint-dir` (no CheckpointWritten events)
            ProgressEvent::BlockStarted { block, elapsed_secs, .. } => {
                st.blocks_done = st.blocks_done.max(*block);
                st.elapsed_secs = st.elapsed_secs.max(*elapsed_secs);
                // the previous block ran from its start stamp to this one
                if let Some((prev, started)) = st.open_block.take() {
                    st.block_secs.insert(prev, (elapsed_secs - started).max(0.0));
                }
                st.open_block = Some((*block, *elapsed_secs));
            }
            ProgressEvent::LayerSolved { layer, worker, elapsed_secs, .. } => {
                st.layers_solved += 1;
                st.last_layer = layer.clone();
                st.elapsed_secs = st.elapsed_secs.max(*elapsed_secs);
                let key = worker.as_deref().unwrap_or(LOCAL_WORKER).to_string();
                // the delivered layer supersedes that worker's live
                // in-solve progress entry
                st.solving.remove(&key);
                *st.workers.entry(key).or_insert(0) += 1;
            }
            ProgressEvent::CheckpointWritten { block, elapsed_secs, .. } => {
                st.checkpoints_written += 1;
                // a checkpoint marks the block complete
                st.blocks_done = st.blocks_done.max(block + 1);
                st.elapsed_secs = st.elapsed_secs.max(*elapsed_secs);
            }
            ProgressEvent::RunFinished { blocks_done, total_secs } => {
                st.blocks_done = st.blocks_done.max(*blocks_done);
                st.total_secs = *total_secs;
                st.elapsed_secs = st.elapsed_secs.max(*total_secs);
                // the last block closes on the run total
                if let Some((prev, started)) = st.open_block.take() {
                    st.block_secs.insert(prev, (total_secs - started).max(0.0));
                }
                st.running = false;
                st.finished = true;
            }
        }
    }

    /// Record one worker keepalive frame (called by the sharded
    /// dispatcher as beats arrive): bumps the per-worker beat count and
    /// replaces that worker's live solve-progress entry.
    pub fn note_heartbeat(&self, worker: &str, hb: &Heartbeat) {
        let mut st = lock(&self.state);
        *st.heartbeats.entry(worker.to_string()).or_insert(0) += 1;
        st.solving
            .insert(worker.to_string(), (hb.job, hb.admm_iter, hb.elapsed_ms));
        drop(st);
        // registry lookup is idempotent; at keepalive cadence (seconds)
        // the name search is noise
        crate::obs::global()
            .gauge(
                "alps_prune_admm_iteration",
                "Latest ADMM iteration reported by each worker's keepalive.",
                &[("worker", worker)],
            )
            .set(hb.admm_iter as f64);
    }

    /// Drop a worker's live solve-progress entry (called by the sharded
    /// dispatcher when it abandons that worker's in-flight jobs): a dead
    /// or rerouted-away worker must not keep showing as "solving" with a
    /// frozen progress reading. The beat count history stays.
    pub fn note_worker_stalled(&self, worker: &str) {
        lock(&self.state).solving.remove(worker);
    }

    /// Record a member joining the dispatcher pool (seed workers at first
    /// dispatch, REGISTERed workers as they arrive): bumps the live fleet
    /// size and appends to the series + event log.
    pub fn note_worker_joined(&self, worker: &str) {
        let mut st = lock(&self.state);
        st.fleet += 1;
        let at = st.elapsed_secs;
        let n = st.fleet;
        st.fleet_series.push((at, n));
        st.fleet_events.push((at, "join".to_string(), worker.to_string()));
    }

    /// Record a member leaving the pool for good (retry budget exhausted,
    /// shutdown): besides the fleet bookkeeping, a permanently departed
    /// worker must not leave a frozen `solving` entry or a stale
    /// `alps_prune_admm_iteration` reading — reroute clears the former
    /// for the reroute case, but only this path handles final departure.
    pub fn note_worker_left(&self, worker: &str) {
        let mut st = lock(&self.state);
        st.fleet = st.fleet.saturating_sub(1);
        let at = st.elapsed_secs;
        let n = st.fleet;
        st.fleet_series.push((at, n));
        st.fleet_events.push((at, "leave".to_string(), worker.to_string()));
        st.solving.remove(worker);
        drop(st);
        // zero (rather than unregister — the registry has no removal) the
        // departed worker's gauge so scrapes stop reading a live-looking
        // iteration count from a dead worker
        crate::obs::global()
            .gauge(
                "alps_prune_admm_iteration",
                "Latest ADMM iteration reported by each worker's keepalive.",
                &[("worker", worker)],
            )
            .set(0.0);
    }

    pub fn snapshot(&self) -> StatusSnapshot {
        lock(&self.state).clone()
    }
}

/// One-shot status endpoint over the shared net layer.
pub struct StatusServer {
    net: NetServer,
}

impl Default for StatusServer {
    fn default() -> Self {
        StatusServer::new()
    }
}

impl StatusServer {
    pub fn new() -> StatusServer {
        StatusServer { net: NetServer::new(ServerConfig::default()) }
    }

    /// Stop the endpoint (the CLI calls this when the run finishes; the
    /// final snapshot has already been served to anyone connected).
    pub fn request_shutdown(&self) {
        self.net.shutdown();
    }

    /// Answer status queries on `listener` until
    /// [`StatusServer::request_shutdown`]. Blocks; run it on its own
    /// thread next to the pruning session.
    pub fn serve(&self, listener: TcpListener, board: &StatusBoard) -> Result<()> {
        let handler = StatusHandler { net: &self.net, board };
        self.net.run(listener, &handler)
    }
}

struct StatusHandler<'a> {
    net: &'a NetServer,
    board: &'a StatusBoard,
}

impl ConnHandler for StatusHandler<'_> {
    fn handle(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(READ_POLL)).context("setting read timeout")?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting write timeout")?;
        let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        let mut stream = stream;
        let first = match read_line_deadline(
            &mut reader,
            MAX_QUERY_LINE,
            self.net.shutdown_flag(),
            QUERY_DEADLINE,
        ) {
            Ok(LineRead::Line(l)) => l,
            Ok(_) => return Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::TimedOut => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        if first.starts_with("GET ") {
            if request_path(&first) == "/metrics" {
                let body = crate::obs::global().render();
                respond_http(
                    &mut reader,
                    &mut stream,
                    MAX_QUERY_LINE,
                    self.net.shutdown_flag(),
                    crate::obs::prometheus::CONTENT_TYPE,
                    &body,
                )?;
            } else {
                let body = self.board.snapshot().to_json();
                respond_http_json(
                    &mut reader,
                    &mut stream,
                    MAX_QUERY_LINE,
                    self.net.shutdown_flag(),
                    &body,
                )?;
            }
        } else {
            // any plain line (canonically `status`) gets the JSON line
            stream.write_all(self.board.snapshot().to_json().as_bytes())?;
        }
        let _ = stream.shutdown(std::net::Shutdown::Write);
        Ok(())
    }

    /// Monitoring must stay live even when idle clients exhaust the
    /// connection cap: an over-cap `GET` probe still gets the snapshot
    /// (or the Prometheus page — the 8-byte sniff covers `GET /met`).
    fn refuse(&self, stream: TcpStream, cap: usize) {
        let mut st = stream;
        let _ = st.set_read_timeout(Some(READ_POLL));
        let _ = st.set_write_timeout(Some(WRITE_TIMEOUT));
        let mut first = [0u8; 8];
        let have = std::io::Read::read(&mut st, &mut first).unwrap_or(0);
        if first[..have].starts_with(b"GET /met") {
            let body = crate::obs::global().render();
            let _ = write_http_response(&mut st, crate::obs::prometheus::CONTENT_TYPE, &body);
        } else if first[..have].starts_with(b"GET ") {
            let _ = write_http_json(&mut st, &self.board.snapshot().to_json());
        } else {
            let _ = writeln!(st, "err - connection limit reached ({cap})");
        }
        finish_refusal(&st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, Read, Write};
    use std::path::PathBuf;
    use std::time::Duration;

    fn sample_events(board: &StatusBoard) {
        board.observe(&ProgressEvent::RunStarted {
            model: "alps-tiny".into(),
            method: "sharded(alps)".into(),
            target: "0.70".into(),
            n_blocks: 2,
        });
        board.observe(&ProgressEvent::BlockStarted { block: 0, n_blocks: 2, elapsed_secs: 0.5 });
        for (i, w) in [Some("127.0.0.1:1"), Some("127.0.0.1:2"), None].iter().enumerate() {
            board.observe(&ProgressEvent::LayerSolved {
                block: 0,
                layer: format!("blocks.0.l{i}"),
                n_in: 8,
                n_out: 8,
                kept: 32,
                total: 64,
                rel_error: 0.1,
                secs: 0.5,
                admm_iters: 3,
                worker: w.map(str::to_string),
                elapsed_secs: 1.0 + i as f64,
            });
        }
        board.observe(&ProgressEvent::CheckpointWritten {
            block: 0,
            path: PathBuf::from("ck"),
            elapsed_secs: 4.0,
        });
        // checkpoint-free runs advance blocks_done through BlockStarted
        board.observe(&ProgressEvent::BlockStarted { block: 1, n_blocks: 2, elapsed_secs: 4.5 });
    }

    #[test]
    fn board_accumulates_events_with_worker_attribution() {
        let board = StatusBoard::new();
        sample_events(&board);
        let st = board.snapshot();
        assert_eq!(st.model, "alps-tiny");
        assert_eq!(st.n_blocks, 2);
        assert_eq!(st.blocks_done, 1);
        assert_eq!(st.layers_solved, 3);
        assert_eq!(st.checkpoints_written, 1);
        assert_eq!(st.last_layer, "blocks.0.l2");
        assert!(st.running && !st.finished);
        assert_eq!(st.workers.get("127.0.0.1:1"), Some(&1));
        assert_eq!(st.workers.get("127.0.0.1:2"), Some(&1));
        assert_eq!(st.workers.get("local"), Some(&1));
        // elapsed tracks the newest stamp; block 0's wall time closed on
        // block 1's start (4.5 - 0.5)
        assert_eq!(st.elapsed_secs, 4.5);
        assert_eq!(st.block_secs.get(&0), Some(&4.0));
        assert!(st.block_secs.get(&1).is_none());

        board.observe(&ProgressEvent::RunFinished { blocks_done: 2, total_secs: 6.5 });
        let st = board.snapshot();
        assert!(st.finished && !st.running);
        assert_eq!(st.blocks_done, 2);
        // the run total closes the final block's wall time (6.5 - 4.5)
        assert_eq!(st.block_secs.get(&1), Some(&2.0));
        let json = st.to_json();
        assert!(json.contains("\"layers_solved\":3"), "{json}");
        assert!(json.contains("\"127.0.0.1:1\":1"), "{json}");
        assert!(json.contains("\"finished\":true"), "{json}");
        assert!(json.contains("\"block_secs\":{\"0\":4,\"1\":2}"), "{json}");
        assert!(json.contains("\"elapsed_secs\":6.5"), "{json}");
    }

    #[test]
    fn board_surfaces_worker_heartbeats() {
        let board = StatusBoard::new();
        sample_events(&board);
        let beat = |job, iter, ms| Heartbeat { job, admm_iter: iter, elapsed_ms: ms };
        board.note_heartbeat("127.0.0.1:1", &beat(7, 120, 900));
        board.note_heartbeat("127.0.0.1:1", &beat(7, 260, 1900));
        board.note_heartbeat("127.0.0.1:2", &beat(8, 0, 40));
        let st = board.snapshot();
        assert_eq!(st.heartbeats.get("127.0.0.1:1"), Some(&2));
        assert_eq!(st.heartbeats.get("127.0.0.1:2"), Some(&1));
        // latest beat wins the live-progress slot
        assert_eq!(st.solving.get("127.0.0.1:1"), Some(&(7, 260, 1900)));
        let json = st.to_json();
        assert!(json.contains("\"heartbeats\":{"), "{json}");
        assert!(json.contains("\"admm_iter\":260"), "{json}");
        // a delivered layer clears that worker's live-progress entry
        board.observe(&ProgressEvent::LayerSolved {
            block: 0,
            layer: "blocks.0.l9".into(),
            n_in: 8,
            n_out: 8,
            kept: 32,
            total: 64,
            rel_error: 0.1,
            secs: 0.5,
            admm_iters: 3,
            worker: Some("127.0.0.1:1".into()),
            elapsed_secs: 5.0,
        });
        assert!(board.snapshot().solving.get("127.0.0.1:1").is_none());
        // a dead/rerouted worker's entry clears too (dispatcher requeue
        // path), while its beat history survives
        board.note_worker_stalled("127.0.0.1:2");
        let st = board.snapshot();
        assert!(st.solving.get("127.0.0.1:2").is_none());
        assert_eq!(st.heartbeats.get("127.0.0.1:2"), Some(&1));
    }

    #[test]
    fn status_server_answers_http_and_line_queries() {
        let board = StatusBoard::new();
        sample_events(&board);
        let server = StatusServer::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let srv = s.spawn(|| server.serve(listener, &board));
            // line query
            let mut st = TcpStream::connect(addr).unwrap();
            st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            writeln!(st, "status").unwrap();
            let mut r = BufReader::new(st);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.starts_with('{'), "line query: {line}");
            assert!(line.contains("\"model\":\"alps-tiny\""), "{line}");
            // HTTP query
            let mut st = TcpStream::connect(addr).unwrap();
            st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            write!(st, "GET /status HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = String::new();
            st.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
            assert!(resp.contains("\"workers\":{"), "{resp}");
            assert!(resp.contains("\"block_secs\":{"), "{resp}");
            // Prometheus scrape on the same port
            let mut st = TcpStream::connect(addr).unwrap();
            st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            write!(st, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut resp = String::new();
            st.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
            assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
            assert!(resp.contains("alps_net_connections_total"), "{resp}");
            server.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn membership_feeds_fleet_series_and_clears_departed_worker_state() {
        let board = StatusBoard::new();
        // join before RunStarted must survive the run-start reset
        board.observe(&ProgressEvent::BlockStarted { block: 0, n_blocks: 1, elapsed_secs: 0.0 });
        board.note_worker_joined("10.0.0.1:7979");
        board.observe(&ProgressEvent::RunStarted {
            model: "alps-tiny".into(),
            method: "sharded(alps)".into(),
            target: "0.70".into(),
            n_blocks: 1,
        });
        board.observe(&ProgressEvent::BlockStarted { block: 0, n_blocks: 1, elapsed_secs: 2.0 });
        board.note_worker_joined("10.0.0.2:7979");
        let beat = Heartbeat { job: 5, admm_iter: 77, elapsed_ms: 300 };
        board.note_heartbeat("10.0.0.2:7979", &beat);
        board.note_worker_left("10.0.0.2:7979");
        let st = board.snapshot();
        assert_eq!(st.fleet, 1);
        assert_eq!(
            st.fleet_series,
            vec![(0.0, 1), (2.0, 2), (2.0, 1)],
            "series tracks size at each membership change"
        );
        assert_eq!(st.fleet_events.len(), 3);
        assert_eq!(st.fleet_events[1].1, "join");
        assert_eq!(st.fleet_events[2], (2.0, "leave".to_string(), "10.0.0.2:7979".to_string()));
        // satellite bugfix: final departure clears the live-solve entry
        // and zeroes the per-worker ADMM gauge (beat history survives)
        assert!(st.solving.get("10.0.0.2:7979").is_none());
        assert_eq!(st.heartbeats.get("10.0.0.2:7979"), Some(&1));
        let page = crate::obs::global().render();
        assert!(
            page.contains("alps_prune_admm_iteration{worker=\"10.0.0.2:7979\"} 0"),
            "{page}"
        );
        let json = st.to_json();
        assert!(json.contains("\"fleet\":1"), "{json}");
        assert!(json.contains("\"fleet_series\":[[0,1],[2,2],[2,1]]"), "{json}");
        assert!(
            json.contains("{\"at\":2,\"event\":\"leave\",\"worker\":\"10.0.0.2:7979\"}"),
            "{json}"
        );
    }

    #[test]
    fn heartbeats_feed_admm_iteration_gauge() {
        let board = StatusBoard::new();
        let hb = Heartbeat { job: 3, admm_iter: 41, elapsed_ms: 800 };
        board.note_heartbeat("127.0.0.1:9", &hb);
        let page = crate::obs::global().render();
        assert!(page.contains("# TYPE alps_prune_admm_iteration gauge"), "{page}");
        assert!(page.contains("alps_prune_admm_iteration{worker=\"127.0.0.1:9\"} 41"), "{page}");
    }
}
