//! `PruneSession` — the typed, builder-style API for the block-by-block
//! pruning pipeline (paper Appendix B.1: prune sequentially; each block's
//! calibration inputs are the outputs of the already-pruned prefix).
//!
//! One session = one end-to-end pruning run:
//!
//! ```text
//! PruneSession::builder()
//!     .calib(seqs)                      // calibration token windows
//!     .target(SparsityTarget::parse("0.7")?)
//!     .method(MethodSpec::Alps(cfg))    // or .engine(Box<dyn Engine>)
//!     .observer(|ev| ...)               // streaming ProgressEvents
//!     .checkpoint_dir("ck").resume(true)
//!     .run(&mut model)?                 // -> RunReport
//! ```
//!
//! Per block the session (1) re-runs the partially pruned model over the
//! calibration set to capture the block's layer inputs, (2) builds one
//! gram matrix per activation tap (wq/wk/wv share one) and retains the
//! tap's raw rows on the problems as shared handles (so an
//! activation-shipping sharded engine can put X on the wire instead of
//! the gram), (3) hands the block's [`LayerJob`]s to the [`Engine`]
//! (native thread-pool fan-out, HLO artifacts, or a persistent remote
//! worker pool), (4) writes the sparse weights back, and (5) optionally
//! checkpoints the full weights plus a JSON manifest so an interrupted
//! run resumes bit-identically from the last finished block.
//!
//! Progress streams through a single observer channel shared by the CLI
//! (verbose printing), benches, tests, and future TCP status endpoints.
//!
//! Crash-safety note: the checkpoint writes weights first, manifest
//! second (each via tmp-file + rename). A kill between the two renames
//! loses at most one block of work — the stale manifest re-prunes the
//! block whose weights were already written, which keeps the run valid
//! but can differ bitwise from an uninterrupted run in that window.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use super::engine::{Engine, LayerJob, NativeEngine};
use super::{LayerProblem, MethodSpec};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::coordinator::report::{LayerReport, RunReport};
use crate::linalg::matmul::{gram, matmul};
use crate::linalg::Matrix;
use crate::model::{prunable_layers, ActivationTap, Model, Weights};
use crate::util::Timer;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Streaming progress from a pruning run. One channel feeds the CLI's
/// verbose output, bench progress lines, and tests.
///
/// Events carry `elapsed_secs` — wall-clock seconds since the session
/// started — so any consumer (verbose lines, the status snapshot, trace
/// sinks) can place them on a shared timeline without its own clock.
#[derive(Clone, Debug)]
pub enum ProgressEvent {
    /// The run began: identity + total block count.
    RunStarted { model: String, method: String, target: String, n_blocks: usize },
    /// A block was skipped because the checkpoint already contains it.
    BlockResumed { block: usize, elapsed_secs: f64 },
    /// Calibration capture for this block is starting.
    BlockStarted { block: usize, n_blocks: usize, elapsed_secs: f64 },
    /// One matrix was solved and written back.
    LayerSolved {
        block: usize,
        layer: String,
        n_in: usize,
        n_out: usize,
        kept: usize,
        total: usize,
        rel_error: f64,
        secs: f64,
        admm_iters: usize,
        /// Pool member that solved it (sharded engines); `None` locally.
        worker: Option<String>,
        /// Since session start (not the same as `secs`, the solve time).
        elapsed_secs: f64,
    },
    /// The per-block checkpoint (weights + manifest) was persisted.
    CheckpointWritten { block: usize, path: PathBuf, elapsed_secs: f64 },
    /// The run finished (possibly early via `stop_after`).
    RunFinished { blocks_done: usize, total_secs: f64 },
}

/// Builder for [`PruneSession`]. `calib` and `target` are required;
/// the engine defaults to native ALPS with paper hyperparameters.
pub struct PruneSessionBuilder<'a> {
    calib: Vec<Vec<u16>>,
    target: Option<SparsityTarget>,
    engine: Option<Box<dyn Engine + 'a>>,
    observer: Option<Box<dyn FnMut(&ProgressEvent) + 'a>>,
    verbose: bool,
    checkpoint_dir: Option<PathBuf>,
    resume: bool,
    stop_after: Option<usize>,
}

impl<'a> PruneSessionBuilder<'a> {
    /// Calibration sequences (token ids, each `seq_len` long). Required.
    pub fn calib(mut self, calib: Vec<Vec<u16>>) -> Self {
        self.calib = calib;
        self
    }

    /// Sparsity target. Required.
    pub fn target(mut self, target: SparsityTarget) -> Self {
        self.target = Some(target);
        self
    }

    /// Solve natively with the given method spec (thread-pool fan-out).
    pub fn method(self, spec: MethodSpec) -> Self {
        self.engine(Box::new(NativeEngine::new(spec)))
    }

    /// Solve with an explicit engine (HLO, or any custom [`Engine`]).
    pub fn engine(mut self, engine: Box<dyn Engine + 'a>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Streaming progress callback; receives every [`ProgressEvent`].
    pub fn observer(mut self, f: impl FnMut(&ProgressEvent) + 'a) -> Self {
        self.observer = Some(Box::new(f));
        self
    }

    /// Print progress lines to stdout (the CLI's default observer).
    pub fn verbose(mut self, verbose: bool) -> Self {
        self.verbose = verbose;
        self
    }

    /// Persist weights + manifest into this directory after every block.
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Resume from the checkpoint in `checkpoint_dir` when one exists
    /// (fresh run otherwise; mismatched checkpoints are an error).
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Stop after the first `blocks` transformer blocks (testing /
    /// simulated interruption; combine with `checkpoint_dir`).
    pub fn stop_after(mut self, blocks: usize) -> Self {
        self.stop_after = Some(blocks);
        self
    }

    /// Validate and assemble the session.
    pub fn build(self) -> Result<PruneSession<'a>> {
        if self.calib.is_empty() {
            bail!("PruneSession requires a non-empty calibration set");
        }
        let Some(target) = self.target else {
            bail!("PruneSession requires a sparsity target");
        };
        if self.resume && self.checkpoint_dir.is_none() {
            bail!("resume requires a checkpoint dir");
        }
        let engine = self
            .engine
            .unwrap_or_else(|| {
                Box::new(NativeEngine::new(MethodSpec::Alps(AlpsConfig::default())))
            });
        Ok(PruneSession {
            calib: self.calib,
            target,
            engine,
            observer: self.observer,
            verbose: self.verbose,
            checkpoint_dir: self.checkpoint_dir,
            resume: self.resume,
            stop_after: self.stop_after,
        })
    }

    /// Build and run in one call.
    pub fn run(self, model: &mut Model) -> Result<RunReport> {
        self.build()?.run(model)
    }
}

/// The block-by-block pruning pipeline. Construct via
/// [`PruneSession::builder`].
pub struct PruneSession<'a> {
    calib: Vec<Vec<u16>>,
    target: SparsityTarget,
    engine: Box<dyn Engine + 'a>,
    observer: Option<Box<dyn FnMut(&ProgressEvent) + 'a>>,
    verbose: bool,
    checkpoint_dir: Option<PathBuf>,
    resume: bool,
    stop_after: Option<usize>,
}

impl<'a> PruneSession<'a> {
    pub fn builder() -> PruneSessionBuilder<'a> {
        PruneSessionBuilder {
            calib: Vec::new(),
            target: None,
            engine: None,
            observer: None,
            verbose: false,
            checkpoint_dir: None,
            resume: false,
            stop_after: None,
        }
    }

    /// Prune `model` in place; returns the per-layer run report.
    pub fn run(&mut self, model: &mut Model) -> Result<RunReport> {
        let result = self.run_inner(model);
        // release engine-held resources (a sharded engine's dispatcher
        // threads and persistent worker connections) whether the run
        // finished or aborted — an early error must not leave detached
        // pool threads or parked connections pinning worker slots for
        // the life of the process
        self.engine.close();
        result
    }

    fn run_inner(&mut self, model: &mut Model) -> Result<RunReport> {
        let total_timer = Timer::start();
        let n_blocks = model.cfg.n_layers;
        let mut report = RunReport {
            method: self.engine.label(),
            target: self.target.label(),
            model: model.cfg.name.clone(),
            ..Default::default()
        };
        self.emit(&ProgressEvent::RunStarted {
            model: report.model.clone(),
            method: report.method.clone(),
            target: report.target.clone(),
            n_blocks,
        });
        let omet = PruneObs::acquire(&report.method);
        let mut run_span = crate::obs::Span::begin("prune_run");
        run_span.set_field("model", &report.model);
        run_span.set_field("method", &report.method);
        run_span.set_field("target", &report.target);

        let engine_config = self.engine.config_digest();
        let calib_dig = calib_digest(&self.calib);
        // fingerprint of the *dense* starting weights, taken before any
        // pruning or checkpoint restore (only needed when checkpointing)
        let init_weights_dig = if self.checkpoint_dir.is_some() {
            weights_digest(&model.weights)
        } else {
            String::new()
        };
        let mut start_block = 0usize;
        if self.resume {
            let Some(dir) = self.checkpoint_dir.clone() else {
                // build() validates this pairing; keep the session
                // constructible-by-hand without an abort path
                bail!("resume requires a checkpoint dir");
            };
            if let Some(ck) = CheckpointState::load(&dir)? {
                ck.validate(&report, n_blocks, &engine_config, &calib_dig, &init_weights_dig)?;
                let weights = Weights::load(&dir.join(CKPT_WEIGHTS))
                    .context("loading checkpointed weights")?;
                if weights.total_params() != model.weights.total_params() {
                    bail!(
                        "checkpoint weights have {} params, model has {}",
                        weights.total_params(),
                        model.weights.total_params()
                    );
                }
                model.weights = weights;
                report.layers = ck.layers;
                start_block = ck.blocks_done;
                for block in 0..start_block {
                    let elapsed_secs = total_timer.elapsed_secs();
                    self.emit(&ProgressEvent::BlockResumed { block, elapsed_secs });
                }
            }
        }

        let end_block = n_blocks.min(self.stop_after.unwrap_or(n_blocks));
        for block in start_block..end_block {
            let elapsed_secs = total_timer.elapsed_secs();
            self.emit(&ProgressEvent::BlockStarted { block, n_blocks, elapsed_secs });
            omet.cur_block.set(block as f64);
            let block_span = crate::obs::Span::begin("block").field("block", &block.to_string());

            // (1) capture this block's layer inputs under current weights
            let inputs = model.forward_collect(&self.calib, block)?;

            // (2) one gram per activation tap (wq/wk/wv share AttnIn); the
            // tap rows themselves move into shared handles so the problems
            // can retain them at zero copy — activation-shipping engines
            // put X on the wire instead of the O(n_in^2) gram
            let mut grams: HashMap<ActivationTap, Matrix> = HashMap::new();
            let mut acts: HashMap<ActivationTap, Arc<Matrix>> = HashMap::new();
            for (tap, x) in inputs.taps {
                grams.insert(tap, gram(&x));
                acts.insert(tap, Arc::new(x));
            }

            // (3) solve the block's matrices through the engine
            let jobs = prunable_layers(block)
                .into_iter()
                .map(|(name, tap)| {
                    let what = model.weights.matrix(&name)?;
                    let mut problem = LayerProblem::from_gram(grams[&tap].clone(), what)?;
                    problem.attach_activations(acts[&tap].clone())?;
                    Ok(LayerJob { name, problem })
                })
                .collect::<Result<Vec<_>>>()?;
            let results = self.engine.solve_block(&jobs, self.target)?;

            // (4) write back + report + stream progress
            for (job, res) in jobs.iter().zip(results) {
                model.weights.set_matrix(&job.name, &res.w)?;
                let rep = LayerReport {
                    name: job.name.clone(),
                    n_in: job.problem.n_in(),
                    n_out: job.problem.n_out(),
                    kept: res.w.nnz(),
                    total: job.problem.n_in() * job.problem.n_out(),
                    rel_error: job.problem.rel_error(&res.w),
                    secs: res.secs,
                    admm_iters: res.admm_iters,
                };
                omet.layers.inc();
                omet.solve_secs.observe(rep.secs);
                if crate::obs::trace::enabled() {
                    let b = block.to_string();
                    let secs = format!("{:.4}", rep.secs);
                    crate::obs::trace::event(
                        "layer_solved",
                        &[
                            ("block", &b),
                            ("layer", &rep.name),
                            ("worker", res.worker.as_deref().unwrap_or("local")),
                            ("secs", &secs),
                        ],
                    );
                }
                self.emit(&ProgressEvent::LayerSolved {
                    block,
                    layer: rep.name.clone(),
                    n_in: rep.n_in,
                    n_out: rep.n_out,
                    kept: rep.kept,
                    total: rep.total,
                    rel_error: rep.rel_error,
                    secs: rep.secs,
                    admm_iters: rep.admm_iters,
                    worker: res.worker.clone(),
                    elapsed_secs: total_timer.elapsed_secs(),
                });
                report.layers.push(rep);
            }

            // (5) per-block checkpoint
            if let Some(dir) = self.checkpoint_dir.clone() {
                let state = CheckpointState {
                    model: report.model.clone(),
                    method: report.method.clone(),
                    target: report.target.clone(),
                    engine_config: engine_config.clone(),
                    calib_digest: calib_dig.clone(),
                    init_weights_digest: init_weights_dig.clone(),
                    n_blocks,
                    blocks_done: block + 1,
                    layers: report.layers.clone(),
                };
                let path = state.save(&dir, model)?;
                omet.checkpoints.inc();
                let elapsed_secs = total_timer.elapsed_secs();
                self.emit(&ProgressEvent::CheckpointWritten { block, path, elapsed_secs });
            }
            omet.blocks.inc();
            block_span.end();
        }

        report.total_secs = total_timer.elapsed_secs();
        run_span.end();
        self.emit(&ProgressEvent::RunFinished {
            blocks_done: start_block.max(end_block),
            total_secs: report.total_secs,
        });
        Ok(report)
    }

    fn emit(&mut self, ev: &ProgressEvent) {
        if self.verbose {
            match ev {
                ProgressEvent::BlockResumed { block, .. } => {
                    println!("  [{block}] resumed from checkpoint");
                }
                ProgressEvent::LayerSolved {
                    block,
                    layer,
                    n_in,
                    n_out,
                    kept,
                    rel_error,
                    secs,
                    elapsed_secs,
                    ..
                } => {
                    println!(
                        "  [{block}] {layer} {n_in}x{n_out} kept={kept} \
                         err={rel_error:.4} ({secs:.2}s, +{elapsed_secs:.1}s)"
                    );
                }
                ProgressEvent::CheckpointWritten { block, path, elapsed_secs } => {
                    println!("  [{block}] checkpoint -> {} (+{elapsed_secs:.1}s)", path.display());
                }
                _ => {}
            }
        }
        if let Some(obs) = &mut self.observer {
            obs(ev);
        }
    }
}

/// Registry handles for session progress (`alps_prune_*`). Acquired once
/// per run; the per-method solve-time histogram carries the method label
/// so a fleet scrape can compare ALPS vs SparseGPT solve cost directly.
struct PruneObs {
    layers: crate::obs::Counter,
    blocks: crate::obs::Counter,
    checkpoints: crate::obs::Counter,
    cur_block: crate::obs::Gauge,
    solve_secs: crate::obs::Histogram,
}

impl PruneObs {
    fn acquire(method: &str) -> PruneObs {
        let r = crate::obs::global();
        PruneObs {
            layers: r.counter("alps_prune_layers_total", "layers solved and written back", &[]),
            blocks: r.counter("alps_prune_blocks_total", "blocks completed", &[]),
            checkpoints: r.counter("alps_prune_checkpoints_total", "checkpoints written", &[]),
            cur_block: r.gauge("alps_prune_block", "block currently being pruned", &[]),
            solve_secs: r.histogram(
                "alps_prune_layer_solve_seconds",
                "per-layer solve time by method",
                &[("method", method)],
                &crate::obs::LATENCY_EDGES,
            ),
        }
    }
}

// ---------------------------------------------------------------- checkpoint

const CKPT_WEIGHTS: &str = "ckpt_weights.bin";
const CKPT_MANIFEST: &str = "ckpt_manifest.json";

/// What the per-block checkpoint manifest records: the run identity —
/// model, method label, target, engine configuration, and a calibration
/// digest, so a resume with different settings is rejected — plus the
/// finished-block count and the per-layer reports accumulated so far.
struct CheckpointState {
    model: String,
    method: String,
    target: String,
    engine_config: String,
    calib_digest: String,
    init_weights_digest: String,
    n_blocks: usize,
    blocks_done: usize,
    layers: Vec<LayerReport>,
}

/// FNV-1a accumulator for the cheap run-identity fingerprints below.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn mix(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Fingerprint of the calibration token stream — catches a changed
/// calibration set on resume.
fn calib_digest(calib: &[Vec<u16>]) -> String {
    let mut h = Fnv::new();
    for seq in calib {
        for &t in seq {
            h.mix(t as u64);
        }
        h.mix(u64::MAX); // sequence boundary
    }
    h.hex()
}

/// Fingerprint of the model weights (names + exact f32 bits) — catches
/// resuming on top of a different base model (different seed/--weights).
fn weights_digest(w: &Weights) -> String {
    let mut h = Fnv::new();
    for (name, t) in &w.tensors {
        for b in name.bytes() {
            h.mix(b as u64);
        }
        h.mix(u64::MAX);
        for v in &t.data {
            h.mix(v.to_bits() as u64);
        }
    }
    h.hex()
}

impl CheckpointState {
    /// Persist weights then manifest (tmp + rename each); returns the
    /// manifest path.
    fn save(&self, dir: &Path, model: &Model) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let wtmp = dir.join("ckpt_weights.tmp");
        model.weights.save(&wtmp)?;
        std::fs::rename(&wtmp, dir.join(CKPT_WEIGHTS))?;
        let mtmp = dir.join("ckpt_manifest.tmp");
        std::fs::write(&mtmp, self.render())?;
        let mpath = dir.join(CKPT_MANIFEST);
        std::fs::rename(&mtmp, &mpath)?;
        Ok(mpath)
    }

    /// Load the manifest from `dir`; `None` when no checkpoint exists.
    fn load(dir: &Path) -> Result<Option<CheckpointState>> {
        let mpath = dir.join(CKPT_MANIFEST);
        if !mpath.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading checkpoint manifest {mpath:?}"))?;
        let v = crate::config::json::Json::parse(&text)
            .with_context(|| format!("parsing checkpoint manifest {mpath:?}"))?;
        let mut layers = Vec::new();
        for l in v.get("layers")?.as_arr()? {
            layers.push(LayerReport {
                name: l.get("name")?.as_str()?.to_string(),
                n_in: l.get("n_in")?.as_usize()?,
                n_out: l.get("n_out")?.as_usize()?,
                kept: l.get("kept")?.as_usize()?,
                total: l.get("total")?.as_usize()?,
                rel_error: l.get("rel_error")?.as_f64()?,
                secs: l.get("secs")?.as_f64()?,
                admm_iters: l.get("admm_iters")?.as_usize()?,
            });
        }
        Ok(Some(CheckpointState {
            model: v.get("model")?.as_str()?.to_string(),
            method: v.get("method")?.as_str()?.to_string(),
            target: v.get("target")?.as_str()?.to_string(),
            engine_config: v.get("engine_config")?.as_str()?.to_string(),
            calib_digest: v.get("calib_digest")?.as_str()?.to_string(),
            init_weights_digest: v.get("init_weights_digest")?.as_str()?.to_string(),
            n_blocks: v.get("n_blocks")?.as_usize()?,
            blocks_done: v.get("blocks_done")?.as_usize()?,
            layers,
        }))
    }

    /// Reject resuming a checkpoint written by a different run setup.
    /// The engine's identity is its *config digest*, not its display
    /// label: backends with identical solver configuration produce
    /// bit-identical blocks (NativeEngine vs ShardedEngine), so a run
    /// may resume a checkpoint across that boundary; the saved `method`
    /// label stays informational.
    #[allow(clippy::too_many_arguments)]
    fn validate(
        &self,
        report: &RunReport,
        n_blocks: usize,
        engine_config: &str,
        calib_digest: &str,
        init_weights_digest: &str,
    ) -> Result<()> {
        if self.model != report.model
            || self.target != report.target
            || self.n_blocks != n_blocks
        {
            bail!(
                "checkpoint mismatch: saved {}/{}/{} over {} blocks, \
                 resuming {}/{}/{} over {} blocks",
                self.model, self.method, self.target, self.n_blocks,
                report.model, report.method, report.target, n_blocks
            );
        }
        if self.engine_config != engine_config {
            bail!(
                "checkpoint mismatch: saved engine config `{}`, \
                 resuming with `{}`",
                self.engine_config,
                engine_config
            );
        }
        if self.calib_digest != calib_digest {
            bail!(
                "checkpoint mismatch: calibration set changed \
                 (saved digest {}, current {})",
                self.calib_digest,
                calib_digest
            );
        }
        if self.init_weights_digest != init_weights_digest {
            bail!(
                "checkpoint mismatch: initial model weights changed \
                 (saved digest {}, current {}) — resume must start from \
                 the same dense model",
                self.init_weights_digest,
                init_weights_digest
            );
        }
        if self.blocks_done > self.n_blocks {
            bail!("corrupt checkpoint: {} of {} blocks done", self.blocks_done, self.n_blocks);
        }
        Ok(())
    }

    fn render(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"model\": \"{}\",\n", json_escape(&self.model)));
        out.push_str(&format!("  \"method\": \"{}\",\n", json_escape(&self.method)));
        out.push_str(&format!("  \"target\": \"{}\",\n", json_escape(&self.target)));
        out.push_str(&format!(
            "  \"engine_config\": \"{}\",\n",
            json_escape(&self.engine_config)
        ));
        out.push_str(&format!(
            "  \"calib_digest\": \"{}\",\n",
            json_escape(&self.calib_digest)
        ));
        out.push_str(&format!(
            "  \"init_weights_digest\": \"{}\",\n",
            json_escape(&self.init_weights_digest)
        ));
        out.push_str(&format!("  \"n_blocks\": {},\n", self.n_blocks));
        out.push_str(&format!("  \"blocks_done\": {},\n", self.blocks_done));
        out.push_str("  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"n_in\": {}, \"n_out\": {}, \
                 \"kept\": {}, \"total\": {}, \"rel_error\": {}, \
                 \"secs\": {}, \"admm_iters\": {}}}{}\n",
                json_escape(&l.name),
                l.n_in,
                l.n_out,
                l.kept,
                l.total,
                json_num(l.rel_error),
                json_num(l.secs),
                l.admm_iters,
                if i + 1 < self.layers.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

/// Finite floats as JSON numbers (Rust's `Display` round-trips f64);
/// non-finite values (which JSON cannot represent) clamp to 0.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

// ------------------------------------------------------- single-layer tools

/// Build a single-layer problem from a model layer + calibration data
/// (used by the Fig.2 / Table 1 single-layer experiments and `alps layer`).
pub fn single_layer_problem(
    model: &Model,
    calib: &[Vec<u16>],
    block: usize,
    layer: &str,
) -> Result<LayerProblem> {
    let inputs = model.forward_collect(calib, block)?;
    let tap = prunable_layers(block)
        .into_iter()
        .find(|(n, _)| n.ends_with(layer))
        .map(|(_, t)| t)
        .ok_or_else(|| anyhow::anyhow!("no layer '{layer}' in block {block}"))?;
    let x = &inputs.taps[&tap];
    let h = gram(x);
    let what = model.weights.matrix(&format!("blocks.{block}.{layer}"))?;
    LayerProblem::from_gram(h, what)
}

/// Dense output of a layer on its calibration inputs — used by tests to
/// cross-check the gram-based error against the direct definition.
pub fn direct_rel_error(x: &Matrix, what: &Matrix, w: &Matrix) -> f64 {
    let dense = matmul(x, what);
    let pruned = matmul(x, w);
    let diff = dense.sub(&pruned);
    diff.fro_norm_sq() / dense.fro_norm_sq().max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;
    use crate::pruning::engine::LayerResult;
    use crate::util::Rng;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn calib_seqs(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
            .collect()
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("alps_session_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn session_prunes_whole_model() {
        let mut model = random_model(0);
        let calib = calib_seqs(4, 8, 24, 1);
        let target = SparsityTarget::Unstructured(0.5);
        let report = PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(MethodSpec::Magnitude)
            .run(&mut model)
            .unwrap();
        assert_eq!(report.layers.len(), 2 * 6);
        assert_eq!(report.method, "mp");
        let s = report.overall_sparsity();
        assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
        let names = model.prunable_names();
        assert!(model.weights.sparsity_of(&names) > 0.45);
    }

    #[test]
    fn alps_beats_mp_through_session() {
        let calib = calib_seqs(4, 8, 24, 2);
        let target = SparsityTarget::Unstructured(0.7);
        let mut m_alps = random_model(3);
        let mut m_mp = random_model(3);
        let r_alps = PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(MethodSpec::Alps(AlpsConfig::default()))
            .run(&mut m_alps)
            .unwrap();
        let r_mp = PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(MethodSpec::Magnitude)
            .run(&mut m_mp)
            .unwrap();
        assert!(
            r_alps.mean_rel_error() < r_mp.mean_rel_error(),
            "alps {} !< mp {}",
            r_alps.mean_rel_error(),
            r_mp.mean_rel_error()
        );
        // ALPS through the session surfaces its ADMM iteration counts
        assert!(r_alps.layers.iter().all(|l| l.admm_iters > 0));
    }

    #[test]
    fn builder_validates_inputs() {
        let t = SparsityTarget::Unstructured(0.5);
        assert!(PruneSession::builder().target(t).build().is_err(), "empty calib");
        let calib = calib_seqs(2, 8, 24, 0);
        assert!(
            PruneSession::builder().calib(calib.clone()).build().is_err(),
            "missing target"
        );
        assert!(
            PruneSession::builder().calib(calib.clone()).target(t).resume(true).build().is_err(),
            "resume without checkpoint dir"
        );
        assert!(PruneSession::builder().calib(calib).target(t).build().is_ok());
    }

    #[test]
    fn observer_receives_event_stream() {
        let mut model = random_model(4);
        let calib = calib_seqs(3, 8, 24, 5);
        let dir = tmpdir("events");
        let events: Rc<RefCell<Vec<String>>> = Rc::new(RefCell::new(Vec::new()));
        let sink = events.clone();
        PruneSession::builder()
            .calib(calib)
            .target(SparsityTarget::Unstructured(0.5))
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .observer(move |ev| {
                sink.borrow_mut().push(
                    match ev {
                        ProgressEvent::RunStarted { .. } => "start",
                        ProgressEvent::BlockResumed { .. } => "resumed",
                        ProgressEvent::BlockStarted { .. } => "block",
                        ProgressEvent::LayerSolved { .. } => "layer",
                        ProgressEvent::CheckpointWritten { .. } => "ckpt",
                        ProgressEvent::RunFinished { .. } => "finish",
                    }
                    .to_string(),
                );
            })
            .run(&mut model)
            .unwrap();
        let evs = events.borrow();
        assert_eq!(evs.first().map(String::as_str), Some("start"));
        assert_eq!(evs.last().map(String::as_str), Some("finish"));
        assert_eq!(evs.iter().filter(|e| *e == "block").count(), 2);
        assert_eq!(evs.iter().filter(|e| *e == "layer").count(), 12);
        assert_eq!(evs.iter().filter(|e| *e == "ckpt").count(), 2);
        assert!(dir.join(CKPT_MANIFEST).exists());
        assert!(dir.join(CKPT_WEIGHTS).exists());
    }

    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let calib = calib_seqs(4, 8, 24, 6);
        let target = SparsityTarget::Unstructured(0.6);
        // Wanda scores depend on the gram, so block 1's solution depends on
        // block 0's pruned weights — a wrong resume would show up here.
        let spec = MethodSpec::Wanda;

        // uninterrupted reference
        let mut m_ref = random_model(7);
        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(spec.clone())
            .run(&mut m_ref)
            .unwrap();

        // interrupted after block 0, then resumed
        let dir = tmpdir("resume");
        let mut m_a = random_model(7);
        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(spec.clone())
            .checkpoint_dir(&dir)
            .stop_after(1)
            .run(&mut m_a)
            .unwrap();
        let mut m_b = random_model(7);
        let resumed_report = PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(spec)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut m_b)
            .unwrap();

        // the resumed report covers every layer (block 0 from the manifest)
        assert_eq!(resumed_report.layers.len(), 12);
        // and the weights are exactly the uninterrupted run's weights
        for (name, t_ref) in &m_ref.weights.tensors {
            let t_res = m_b.weights.tensors.get(name).unwrap();
            assert_eq!(t_ref.shape, t_res.shape, "{name}");
            assert_eq!(t_ref.data, t_res.data, "tensor '{name}' differs after resume");
        }
    }

    #[test]
    fn resume_rejects_mismatched_checkpoint() {
        let calib = calib_seqs(3, 8, 24, 8);
        let target = SparsityTarget::Unstructured(0.5);
        let dir = tmpdir("mismatch");
        let mut m = random_model(9);
        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .stop_after(1)
            .run(&mut m)
            .unwrap();
        // different method -> reject
        let mut m2 = random_model(9);
        let err = PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(MethodSpec::Magnitude)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut m2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint mismatch"), "{err}");
        // different target -> reject
        let err = PruneSession::builder()
            .calib(calib.clone())
            .target(SparsityTarget::Unstructured(0.9))
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut random_model(9))
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint mismatch"), "{err}");
        // different calibration set -> reject
        let err = PruneSession::builder()
            .calib(calib_seqs(3, 8, 24, 999))
            .target(target)
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut random_model(9))
            .unwrap_err()
            .to_string();
        assert!(err.contains("calibration set changed"), "{err}");
    }

    #[test]
    fn resume_rejects_changed_base_weights() {
        // same model config, different random seed -> different dense
        // weights -> resume must refuse rather than silently discard them
        let calib = calib_seqs(3, 8, 24, 30);
        let target = SparsityTarget::Unstructured(0.5);
        let dir = tmpdir("baseweights");
        let mut m = random_model(31);
        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .stop_after(1)
            .run(&mut m)
            .unwrap();
        let err = PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(MethodSpec::Wanda)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut random_model(32))
            .unwrap_err()
            .to_string();
        assert!(err.contains("initial model weights changed"), "{err}");
    }

    #[test]
    fn resume_rejects_changed_hyperparameters() {
        // same method label, different solver config -> reject
        let calib = calib_seqs(3, 8, 24, 20);
        let target = SparsityTarget::Unstructured(0.5);
        let dir = tmpdir("hyper");
        let mut m = random_model(21);
        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(MethodSpec::DsNoT(crate::config::DsNoTConfig::default()))
            .checkpoint_dir(&dir)
            .stop_after(1)
            .run(&mut m)
            .unwrap();
        let err = PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(MethodSpec::DsNoT(crate::config::DsNoTConfig {
                max_cycles: 1,
                ..Default::default()
            }))
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut random_model(21))
            .unwrap_err()
            .to_string();
        assert!(err.contains("engine config"), "{err}");
    }

    #[test]
    fn resume_without_checkpoint_runs_fresh() {
        let calib = calib_seqs(3, 8, 24, 10);
        let dir = tmpdir("fresh");
        let mut m = random_model(11);
        let report = PruneSession::builder()
            .calib(calib)
            .target(SparsityTarget::Unstructured(0.5))
            .method(MethodSpec::Magnitude)
            .checkpoint_dir(&dir)
            .resume(true)
            .run(&mut m)
            .unwrap();
        assert_eq!(report.layers.len(), 12);
    }

    /// An engine that zeroes every layer — used to prove pruned weights
    /// feed forward into later blocks' calibration statistics.
    struct ZeroEngine;
    impl Engine for ZeroEngine {
        fn label(&self) -> String {
            "zero".into()
        }
        fn solve_layer(
            &self,
            problem: &LayerProblem,
            _target: SparsityTarget,
        ) -> Result<LayerResult> {
            Ok(LayerResult {
                w: Matrix::zeros(problem.n_in(), problem.n_out()),
                secs: 0.0,
                admm_iters: 0,
                worker: None,
            })
        }
    }

    #[test]
    fn pruned_block_propagates_into_later_grams() {
        let calib = calib_seqs(4, 8, 24, 12);
        let dense = random_model(13);

        // block 1's attention-input gram under dense weights (captured
        // twice to confirm the forward pass itself is deterministic)
        let g_dense = {
            let inputs = dense.forward_collect(&calib, 1).unwrap();
            gram(&inputs.taps[&ActivationTap::AttnIn])
        };
        let g_dense2 = {
            let inputs = dense.forward_collect(&calib, 1).unwrap();
            gram(&inputs.taps[&ActivationTap::AttnIn])
        };
        assert_eq!(g_dense, g_dense2, "forward_collect must be deterministic");

        // zero out block 0 only; block 1's calibration inputs must change
        let mut pruned = random_model(13);
        PruneSession::builder()
            .calib(calib.clone())
            .target(SparsityTarget::Unstructured(0.5))
            .engine(Box::new(ZeroEngine))
            .stop_after(1)
            .run(&mut pruned)
            .unwrap();
        let g_pruned = {
            let inputs = pruned.forward_collect(&calib, 1).unwrap();
            gram(&inputs.taps[&ActivationTap::AttnIn])
        };
        let max_diff = g_dense
            .data
            .iter()
            .zip(&g_pruned.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff > 1e-3,
            "block 1 grams unchanged after zeroing block 0 (max diff {max_diff})"
        );
    }

    #[test]
    fn single_layer_problem_builds() {
        let model = random_model(4);
        let calib = calib_seqs(3, 8, 24, 5);
        let p = single_layer_problem(&model, &calib, 0, "attn.wq").unwrap();
        assert_eq!(p.n_in(), 16);
        assert_eq!(p.n_out(), 16);
        assert!(single_layer_problem(&model, &calib, 0, "nope").is_err());
    }

    #[test]
    fn gram_error_matches_direct_error() {
        let model = random_model(5);
        let calib = calib_seqs(3, 8, 24, 6);
        let inputs = model.forward_collect(&calib, 0).unwrap();
        let x = &inputs.taps[&ActivationTap::AttnIn];
        let what = model.weights.matrix("blocks.0.attn.wq").unwrap();
        let p = LayerProblem::from_activations(x, &what).unwrap();
        let w = crate::pruning::projection::topk_project(&what, 100);
        let e1 = p.rel_error(&w);
        let e2 = direct_rel_error(x, &what, &w);
        assert!((e1 - e2).abs() < 1e-3, "{e1} vs {e2}");
    }

    #[test]
    fn manifest_roundtrips() {
        let st = CheckpointState {
            model: "m\"x".into(),
            method: "alps".into(),
            target: "0.70".into(),
            engine_config: "Alps(AlpsConfig { rho0: 0.1 })".into(),
            calib_digest: "00ff00ff00ff00ff".into(),
            init_weights_digest: "1234abcd1234abcd".into(),
            n_blocks: 4,
            blocks_done: 2,
            layers: vec![LayerReport {
                name: "blocks.0.attn.wq".into(),
                n_in: 16,
                n_out: 16,
                kept: 128,
                total: 256,
                rel_error: 0.125,
                secs: 1.5,
                admm_iters: 42,
            }],
        };
        let dir = tmpdir("manifest");
        std::fs::write(dir.join(CKPT_MANIFEST), st.render()).unwrap();
        let back = CheckpointState::load(&dir).unwrap().unwrap();
        assert_eq!(back.model, "m\"x");
        assert_eq!(back.engine_config, "Alps(AlpsConfig { rho0: 0.1 })");
        assert_eq!(back.calib_digest, "00ff00ff00ff00ff");
        assert_eq!(back.init_weights_digest, "1234abcd1234abcd");
        assert_eq!(back.blocks_done, 2);
        assert_eq!(back.n_blocks, 4);
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.layers[0].kept, 128);
        assert_eq!(back.layers[0].rel_error, 0.125);
        assert_eq!(back.layers[0].admm_iters, 42);
        // no checkpoint at an empty dir
        assert!(CheckpointState::load(&tmpdir("absent")).unwrap().is_none());
    }
}
