//! Wire codec for the distributed pruning protocol.
//!
//! One [`SolveRequest`] carries everything a stateless worker needs to
//! solve one layer: the dense weights, the calibration gram matrix, the
//! full [`MethodSpec`] (hyperparameters included), and the
//! [`SparsityTarget`]. The worker rebuilds the [`LayerProblem`] with
//! [`LayerProblem::from_gram`] — the derived quantities (`G = H What`,
//! the normalizer) are recomputed from bit-identical inputs by the same
//! deterministic kernels, so a remote solve is bit-identical to a local
//! one.
//!
//! Encoding is little-endian and versioned at the frame layer
//! ([`crate::net::framing`]); payload tags:
//!
//! * [`tag::SOLVE`] — coordinator -> worker, a [`SolveRequest`];
//! * [`tag::RESULT`] — worker -> coordinator, a [`SolveResponse`];
//! * [`tag::ERROR`] — worker -> coordinator, `[u64 job][string msg]`
//!   (solver-level failure: deterministic, so the coordinator aborts the
//!   block instead of retrying elsewhere; protocol-level failures carry
//!   the `u64::MAX` sentinel instead of a job id);
//! * [`tag::BUSY`] — worker -> coordinator, same payload shape: the
//!   worker is at its connection cap; retry after a backoff.
//!
//! f32/f64 round-trip through `to_le_bytes`/`from_le_bytes` exactly, so
//! the transport never perturbs a single bit of the matrices.

use super::{LayerProblem, MethodSpec};
use crate::config::{AlpsConfig, DsNoTConfig, SparseGptConfig, SparsityTarget};
use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// Payload tags inside the `net` frame header.
pub mod tag {
    /// Coordinator -> worker: solve one layer.
    pub const SOLVE: u8 = 1;
    /// Worker -> coordinator: solved layer.
    pub const RESULT: u8 = 2;
    /// Worker -> coordinator: solver error (job id + message). Solver
    /// failures are deterministic — the coordinator aborts the block
    /// rather than retrying the job elsewhere.
    pub const ERROR: u8 = 3;
    /// Worker -> coordinator: transient transport-level refusal
    /// (connection cap reached). Retryable — the coordinator backs off
    /// and reconnects instead of aborting the run.
    pub const BUSY: u8 = 4;
}

/// One layer-solve job shipped to a worker.
pub struct SolveRequest {
    /// Coordinator-side job index; echoed back in the response so
    /// pipelined requests reassemble deterministically.
    pub job: u64,
    pub target: SparsityTarget,
    pub spec: MethodSpec,
    /// Dense weights What `[n_in, n_out]`.
    pub what: Matrix,
    /// Calibration gram H = X^T X `[n_in, n_in]`.
    pub h: Matrix,
}

/// Encode a solve request from borrowed parts — the coordinator's send
/// path, which must not deep-copy a layer's matrices just to serialize
/// them (a wide layer's gram alone can be gigabytes).
pub fn encode_solve(
    job: u64,
    target: SparsityTarget,
    spec: &MethodSpec,
    what: &Matrix,
    h: &Matrix,
) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(job);
    put_target(&mut e, target);
    put_spec(&mut e, spec);
    put_matrix(&mut e, what);
    put_matrix(&mut e, h);
    e.0
}

impl SolveRequest {
    pub fn encode(&self) -> Vec<u8> {
        encode_solve(self.job, self.target, &self.spec, &self.what, &self.h)
    }

    pub fn decode(buf: &[u8]) -> Result<SolveRequest> {
        let mut d = Dec::new(buf);
        let req = SolveRequest {
            job: d.u64()?,
            target: get_target(&mut d)?,
            spec: get_spec(&mut d)?,
            what: get_matrix(&mut d)?,
            h: get_matrix(&mut d)?,
        };
        d.finish()?;
        Ok(req)
    }

    /// Rebuild the layer problem exactly as the coordinator had it.
    pub fn problem(&self) -> Result<LayerProblem> {
        LayerProblem::from_gram(self.h.clone(), self.what.clone())
    }
}

/// A solved layer coming back from a worker.
pub struct SolveResponse {
    pub job: u64,
    /// Worker-side wall-clock seconds for the solve.
    pub secs: f64,
    /// ADMM iterations (ALPS specs only, 0 otherwise).
    pub admm_iters: u64,
    /// Pruned weights `[n_in, n_out]`.
    pub w: Matrix,
}

impl SolveResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.job);
        e.f64(self.secs);
        e.u64(self.admm_iters);
        put_matrix(&mut e, &self.w);
        e.0
    }

    pub fn decode(buf: &[u8]) -> Result<SolveResponse> {
        let mut d = Dec::new(buf);
        let resp = SolveResponse {
            job: d.u64()?,
            secs: d.f64()?,
            admm_iters: d.u64()?,
            w: get_matrix(&mut d)?,
        };
        d.finish()?;
        Ok(resp)
    }
}

/// Encode a worker-side solver failure for `tag::ERROR`.
pub fn encode_error(job: u64, msg: &str) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(job);
    e.str(msg);
    e.0
}

/// Decode a `tag::ERROR` payload into (job, message).
pub fn decode_error(buf: &[u8]) -> Result<(u64, String)> {
    let mut d = Dec::new(buf);
    let job = d.u64()?;
    let msg = d.str()?;
    d.finish()?;
    Ok((job, msg))
}

// ------------------------------------------------------------ primitives

/// Append-only little-endian encoder.
struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Enc {
        Enc(Vec::new())
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked little-endian decoder.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(len)?).into_owned())
    }

    /// Reject trailing garbage — catches desynced peers early.
    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after payload", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---------------------------------------------------------- domain types

fn put_matrix(e: &mut Enc, m: &Matrix) {
    e.u32(m.rows as u32);
    e.u32(m.cols as u32);
    // one up-front reservation: a gigabyte-scale gram must not be built
    // through doubling reallocations that memcpy the whole buffer
    e.0.reserve(m.data.len() * 4);
    for &v in &m.data {
        e.0.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_matrix(d: &mut Dec) -> Result<Matrix> {
    let rows = d.u32()? as usize;
    let cols = d.u32()? as usize;
    // overflow-proof size check before any allocation
    let bytes = rows.checked_mul(cols).and_then(|n| n.checked_mul(4));
    let Some(bytes) = bytes.filter(|&b| b <= d.buf.len() - d.pos) else {
        bail!("matrix {rows}x{cols} larger than remaining payload");
    };
    let raw = d.take(bytes)?;
    let data = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Matrix::from_vec(rows, cols, data))
}

fn put_target(e: &mut Enc, t: SparsityTarget) {
    match t {
        SparsityTarget::Unstructured(s) => {
            e.u8(0);
            e.f64(s);
        }
        SparsityTarget::NM { n, m } => {
            e.u8(1);
            e.u32(n as u32);
            e.u32(m as u32);
        }
    }
}

fn get_target(d: &mut Dec) -> Result<SparsityTarget> {
    match d.u8()? {
        0 => Ok(SparsityTarget::Unstructured(d.f64()?)),
        1 => Ok(SparsityTarget::NM { n: d.u32()? as usize, m: d.u32()? as usize }),
        k => bail!("unknown sparsity-target kind {k}"),
    }
}

fn put_alps(e: &mut Enc, c: &AlpsConfig) {
    e.f32(c.rho0);
    e.u32(c.update_every as u32);
    e.f32(c.rho_factors.0);
    e.f32(c.rho_factors.1);
    e.f32(c.rho_factors.2);
    e.f64(c.support_bands.0);
    e.f64(c.support_bands.1);
    e.u32(c.max_iters as u32);
    e.u32(c.pcg_iters as u32);
    e.u8(c.diag_scaling as u8);
    e.f32(c.damp);
}

fn get_alps(d: &mut Dec) -> Result<AlpsConfig> {
    Ok(AlpsConfig {
        rho0: d.f32()?,
        update_every: d.u32()? as usize,
        rho_factors: (d.f32()?, d.f32()?, d.f32()?),
        support_bands: (d.f64()?, d.f64()?),
        max_iters: d.u32()? as usize,
        pcg_iters: d.u32()? as usize,
        diag_scaling: d.u8()? != 0,
        damp: d.f32()?,
    })
}

fn put_spec(e: &mut Enc, spec: &MethodSpec) {
    match spec {
        MethodSpec::Magnitude => e.u8(0),
        MethodSpec::Wanda => e.u8(1),
        MethodSpec::SparseGpt(c) => {
            e.u8(2);
            e.u32(c.block_size as u32);
            e.f32(c.percdamp);
        }
        MethodSpec::DsNoT(c) => {
            e.u8(3);
            e.u32(c.max_cycles as u32);
            e.f64(c.min_gain);
        }
        MethodSpec::Alps(c) => {
            e.u8(4);
            put_alps(e, c);
        }
        MethodSpec::AlpsStructured(c) => {
            e.u8(5);
            put_alps(e, c);
        }
    }
}

fn get_spec(d: &mut Dec) -> Result<MethodSpec> {
    Ok(match d.u8()? {
        0 => MethodSpec::Magnitude,
        1 => MethodSpec::Wanda,
        2 => MethodSpec::SparseGpt(SparseGptConfig {
            block_size: d.u32()? as usize,
            percdamp: d.f32()?,
        }),
        3 => MethodSpec::DsNoT(DsNoTConfig {
            max_cycles: d.u32()? as usize,
            min_gain: d.f64()?,
        }),
        4 => MethodSpec::Alps(get_alps(d)?),
        5 => MethodSpec::AlpsStructured(get_alps(d)?),
        k => bail!("unknown method-spec kind {k}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn specimen_specs() -> Vec<MethodSpec> {
        vec![
            MethodSpec::Magnitude,
            MethodSpec::Wanda,
            MethodSpec::SparseGpt(SparseGptConfig { block_size: 48, percdamp: 0.03 }),
            MethodSpec::DsNoT(DsNoTConfig { max_cycles: 17, min_gain: 1e-7 }),
            MethodSpec::Alps(AlpsConfig { rho0: 0.25, max_iters: 123, ..Default::default() }),
            MethodSpec::AlpsStructured(AlpsConfig { pcg_iters: 3, ..Default::default() }),
        ]
    }

    #[test]
    fn request_roundtrips_bit_exact() {
        let mut rng = Rng::new(1);
        for (i, spec) in specimen_specs().into_iter().enumerate() {
            let what = Matrix::randn(12, 6, &mut rng);
            let h = Matrix::randn(12, 12, &mut rng);
            let target = if i % 2 == 0 {
                SparsityTarget::Unstructured(0.65)
            } else {
                SparsityTarget::NM { n: 2, m: 4 }
            };
            let req = SolveRequest {
                job: 41 + i as u64,
                target,
                spec: spec.clone(),
                what: what.clone(),
                h: h.clone(),
            };
            let back = SolveRequest::decode(&req.encode()).unwrap();
            assert_eq!(back.job, 41 + i as u64);
            assert_eq!(back.target, target);
            assert_eq!(back.spec, spec);
            // bit-exact matrices: compare the raw f32 bit patterns
            let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&back.what), bits(&what));
            assert_eq!(bits(&back.h), bits(&h));
        }
    }

    #[test]
    fn response_roundtrips() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 4, &mut rng);
        let resp =
            SolveResponse { job: 7, secs: 0.125, admm_iters: 42, w: w.clone() };
        let back = SolveResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back.job, 7);
        assert_eq!(back.secs, 0.125);
        assert_eq!(back.admm_iters, 42);
        assert_eq!(back.w, w);
    }

    #[test]
    fn error_payload_roundtrips() {
        let buf = encode_error(3, "structured ALPS does not support N:M targets");
        let (job, msg) = decode_error(&buf).unwrap();
        assert_eq!(job, 3);
        assert!(msg.contains("N:M"));
    }

    #[test]
    fn truncated_and_trailing_payloads_rejected() {
        let mut rng = Rng::new(3);
        let req = SolveRequest {
            job: 1,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Wanda,
            what: Matrix::randn(4, 4, &mut rng),
            h: Matrix::randn(4, 4, &mut rng),
        };
        let buf = req.encode();
        // truncation at every prefix must error, never panic
        for cut in [0, 1, 8, 9, buf.len() / 2, buf.len() - 1] {
            assert!(SolveRequest::decode(&buf[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage rejected
        let mut long = buf.clone();
        long.push(0);
        assert!(SolveRequest::decode(&long).is_err());
        // oversized matrix header rejected before allocation
        let mut huge = Vec::new();
        let mut e = Enc::new();
        e.u64(1);
        put_target(&mut e, SparsityTarget::Unstructured(0.5));
        put_spec(&mut e, &MethodSpec::Wanda);
        e.u32(u32::MAX);
        e.u32(u32::MAX);
        huge.extend_from_slice(&e.0);
        let err = SolveRequest::decode(&huge).unwrap_err().to_string();
        assert!(err.contains("larger than remaining"), "{err}");
    }

    #[test]
    fn rebuilt_problem_matches_local_construction() {
        use crate::pruning::testutil::random_problem;
        let p = random_problem(10, 5, 40, 9);
        let req = SolveRequest {
            job: 0,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Magnitude,
            what: p.what.clone(),
            h: p.h.clone(),
        };
        let back = SolveRequest::decode(&req.encode()).unwrap();
        let q = back.problem().unwrap();
        // the derived quantities are recomputed bit-identically
        assert_eq!(q.g, p.g);
        assert_eq!(q.denom, p.denom);
    }
}
