//! Wire codec for the distributed pruning protocol (frame version 3).
//!
//! One [`SolveRequest`] carries everything a stateless worker needs to
//! solve one layer: the dense weights, the calibration statistics, the
//! full [`MethodSpec`] (hyperparameters included), and the
//! [`SparsityTarget`]. Calibration travels in one of two forms
//! ([`Calib`]):
//!
//! * **Gram** — the precomputed `H = X^T X` `[n_in, n_in]`, the v1
//!   layout; the worker rebuilds the problem with
//!   [`LayerProblem::from_gram`].
//! * **Activations** — the raw calibration rows `X [n, n_in]`; the worker
//!   builds the gram itself with the same deterministic
//!   `linalg::matmul::gram` kernel, then proceeds through
//!   [`LayerProblem::from_gram`] exactly as the gram path does. For wide
//!   layers this cuts the per-layer wire payload from O(n_in^2) to
//!   O(n·n_in) whenever `n < n_in`.
//!
//! Either way the derived quantities (`G = H What`, the normalizer) are
//! recomputed from bit-identical inputs by the same deterministic
//! kernels, so a remote solve is bit-identical to a local one.
//!
//! Encoding is little-endian and versioned at the frame layer
//! ([`crate::net::framing`], `FRAME_VERSION = 3`); payload tags:
//!
//! * [`tag::SOLVE`] — coordinator -> worker, a [`SolveRequest`];
//! * [`tag::RESULT`] — worker -> coordinator, a [`SolveResponse`];
//! * [`tag::ERROR`] — worker -> coordinator, `[u64 job][string msg]`
//!   (solver-level failure: deterministic, so the coordinator aborts the
//!   block instead of retrying elsewhere; protocol-level failures carry
//!   the `u64::MAX` sentinel instead of a job id);
//! * [`tag::BUSY`] — worker -> coordinator, same payload shape: the
//!   worker is at its connection cap; retry after a backoff;
//! * [`tag::HEARTBEAT`] — worker -> coordinator, a [`Heartbeat`]: emitted
//!   periodically while a solve is in progress so the coordinator can
//!   tell a slow solve from a dead worker and reroute on missed beats
//!   instead of waiting out its (much longer) idle timeout;
//! * [`tag::REGISTER`] — worker -> coordinator (new in version 3), the
//!   worker's advertised `host:port` serve address, sent to the
//!   coordinator's registration endpoint to join the fleet mid-run; the
//!   coordinator acks by echoing the frame back verbatim.
//!
//! Every decoder is bounds-checked: truncated or corrupt payloads come
//! back as a `malformed frame` error, never a panic — a desynced or
//! hostile peer cannot crash the reader.
//!
//! f32/f64 round-trip through `to_le_bytes`/`from_le_bytes` exactly, so
//! the transport never perturbs a single bit of the matrices.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use super::{LayerProblem, MethodSpec};
use crate::config::{AlpsConfig, DsNoTConfig, SparseGptConfig, SparsityTarget};
use crate::linalg::Matrix;
use anyhow::{anyhow, bail, Result};

/// Payload tags inside the `net` frame header.
pub mod tag {
    /// Coordinator -> worker: solve one layer.
    pub const SOLVE: u8 = 1;
    /// Worker -> coordinator: solved layer.
    pub const RESULT: u8 = 2;
    /// Worker -> coordinator: solver error (job id + message). Solver
    /// failures are deterministic — the coordinator aborts the block
    /// rather than retrying the job elsewhere.
    pub const ERROR: u8 = 3;
    /// Worker -> coordinator: transient transport-level refusal
    /// (connection cap reached). Retryable — the coordinator backs off
    /// and reconnects instead of aborting the run.
    pub const BUSY: u8 = 4;
    /// Worker -> coordinator: periodic liveness beacon while a solve is
    /// in progress, carrying the job id plus ADMM iteration / elapsed
    /// progress. Purely advisory: the coordinator uses the *absence* of
    /// beats to declare a worker dead.
    pub const HEARTBEAT: u8 = 5;
    /// Worker -> coordinator (version 3): dynamic-membership
    /// announcement carrying the worker's advertised serve address. Sent
    /// to the coordinator's registration endpoint — not a worker's serve
    /// port — and echoed back verbatim as the ack.
    pub const REGISTER: u8 = 6;
}

/// Calibration statistics of one solve request (owned form).
#[derive(Clone)]
pub enum Calib {
    /// Precomputed gram `H = X^T X` `[n_in, n_in]`.
    Gram(Matrix),
    /// Raw calibration activations `X [n, n_in]`; the worker computes
    /// the gram with the same deterministic kernel the coordinator uses.
    Activations(Matrix),
}

/// Borrowed form of [`Calib`] for the coordinator's send path, which must
/// not deep-copy a layer's matrices just to serialize them (a wide
/// layer's gram alone can be gigabytes).
#[derive(Clone, Copy)]
pub enum CalibRef<'a> {
    Gram(&'a Matrix),
    Activations(&'a Matrix),
}

impl Calib {
    fn borrowed(&self) -> CalibRef<'_> {
        match self {
            Calib::Gram(h) => CalibRef::Gram(h),
            Calib::Activations(x) => CalibRef::Activations(x),
        }
    }
}

/// One layer-solve job shipped to a worker.
pub struct SolveRequest {
    /// Coordinator-side job index; echoed back in the response so
    /// pipelined requests reassemble deterministically.
    pub job: u64,
    pub target: SparsityTarget,
    pub spec: MethodSpec,
    /// Dense weights What `[n_in, n_out]`.
    pub what: Matrix,
    /// Calibration statistics: gram, or activations for worker-side gram.
    pub calib: Calib,
}

/// Encode a solve request from borrowed parts — the coordinator's send
/// path (no deep copies of the possibly huge matrices).
pub fn encode_solve(
    job: u64,
    target: SparsityTarget,
    spec: &MethodSpec,
    what: &Matrix,
    calib: CalibRef<'_>,
) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(job);
    put_target(&mut e, target);
    put_spec(&mut e, spec);
    put_matrix(&mut e, what);
    match calib {
        CalibRef::Gram(h) => {
            e.u8(0);
            put_matrix(&mut e, h);
        }
        CalibRef::Activations(x) => {
            e.u8(1);
            put_matrix(&mut e, x);
        }
    }
    e.0
}

impl SolveRequest {
    pub fn encode(&self) -> Vec<u8> {
        encode_solve(self.job, self.target, &self.spec, &self.what, self.calib.borrowed())
    }

    pub fn decode(buf: &[u8]) -> Result<SolveRequest> {
        Self::decode_inner(buf).map_err(|e| anyhow!("malformed frame: {e}"))
    }

    fn decode_inner(buf: &[u8]) -> Result<SolveRequest> {
        let mut d = Dec::new(buf);
        let job = d.u64()?;
        let target = get_target(&mut d)?;
        let spec = get_spec(&mut d)?;
        let what = get_matrix(&mut d)?;
        let calib = match d.u8()? {
            0 => Calib::Gram(get_matrix(&mut d)?),
            1 => Calib::Activations(get_matrix(&mut d)?),
            k => bail!("unknown calibration kind {k}"),
        };
        d.finish()?;
        Ok(SolveRequest { job, target, spec, what, calib })
    }

    /// Rebuild the layer problem exactly as the coordinator had it: a
    /// shipped gram feeds [`LayerProblem::from_gram`]; shipped
    /// activations go through the same `gram` kernel the coordinator's
    /// session uses, so the resulting H is bit-identical. Deliberately
    /// NOT [`LayerProblem::from_activations`]: that constructor retains a
    /// deep copy of X on the problem, which the worker (already holding X
    /// in the request) has no use for.
    pub fn problem(&self) -> Result<LayerProblem> {
        match &self.calib {
            Calib::Gram(h) => LayerProblem::from_gram(h.clone(), self.what.clone()),
            Calib::Activations(x) => {
                LayerProblem::from_gram(crate::linalg::matmul::gram(x), self.what.clone())
            }
        }
    }
}

/// A solved layer coming back from a worker.
pub struct SolveResponse {
    pub job: u64,
    /// Worker-side wall-clock seconds for the solve.
    pub secs: f64,
    /// ADMM iterations (ALPS specs only, 0 otherwise).
    pub admm_iters: u64,
    /// Pruned weights `[n_in, n_out]`.
    pub w: Matrix,
}

impl SolveResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.job);
        e.f64(self.secs);
        e.u64(self.admm_iters);
        put_matrix(&mut e, &self.w);
        e.0
    }

    pub fn decode(buf: &[u8]) -> Result<SolveResponse> {
        Self::decode_inner(buf).map_err(|e| anyhow!("malformed frame: {e}"))
    }

    fn decode_inner(buf: &[u8]) -> Result<SolveResponse> {
        let mut d = Dec::new(buf);
        let resp = SolveResponse {
            job: d.u64()?,
            secs: d.f64()?,
            admm_iters: d.u64()?,
            w: get_matrix(&mut d)?,
        };
        d.finish()?;
        Ok(resp)
    }
}

/// Worker liveness beacon, emitted every `heartbeat_every` while a solve
/// runs on the connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Heartbeat {
    /// The job currently being solved on this connection.
    pub job: u64,
    /// ADMM iterations completed so far (0 for non-ALPS methods and
    /// during problem rebuild / gram computation).
    pub admm_iter: u64,
    /// Milliseconds since this solve started on the worker.
    pub elapsed_ms: u64,
}

/// Encode a [`Heartbeat`] for `tag::HEARTBEAT`.
pub fn encode_heartbeat(hb: Heartbeat) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(hb.job);
    e.u64(hb.admm_iter);
    e.u64(hb.elapsed_ms);
    e.0
}

/// Decode a `tag::HEARTBEAT` payload.
pub fn decode_heartbeat(buf: &[u8]) -> Result<Heartbeat> {
    fn inner(buf: &[u8]) -> Result<Heartbeat> {
        let mut d = Dec::new(buf);
        let hb =
            Heartbeat { job: d.u64()?, admm_iter: d.u64()?, elapsed_ms: d.u64()? };
        d.finish()?;
        Ok(hb)
    }
    inner(buf).map_err(|e| anyhow!("malformed frame: {e}"))
}

/// Encode a worker-side solver failure for `tag::ERROR`.
pub fn encode_error(job: u64, msg: &str) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(job);
    e.str(msg);
    e.0
}

/// Decode a `tag::ERROR` payload into (job, message).
pub fn decode_error(buf: &[u8]) -> Result<(u64, String)> {
    fn inner(buf: &[u8]) -> Result<(u64, String)> {
        let mut d = Dec::new(buf);
        let job = d.u64()?;
        let msg = d.str()?;
        d.finish()?;
        Ok((job, msg))
    }
    inner(buf).map_err(|e| anyhow!("malformed frame: {e}"))
}

/// Encode a `tag::REGISTER` payload: the worker's advertised `host:port`
/// serve address (where the coordinator should dial back for solves).
pub fn encode_register(addr: &str) -> Vec<u8> {
    let mut e = Enc::new();
    e.str(addr);
    e.0
}

/// Decode a `tag::REGISTER` payload into the advertised worker address.
/// An empty address is rejected — the coordinator could never dial it.
pub fn decode_register(buf: &[u8]) -> Result<String> {
    fn inner(buf: &[u8]) -> Result<String> {
        let mut d = Dec::new(buf);
        let addr = d.str()?;
        if addr.is_empty() {
            bail!("empty worker address");
        }
        d.finish()?;
        Ok(addr)
    }
    inner(buf).map_err(|e| anyhow!("malformed frame: {e}"))
}

// ------------------------------------------------------------ primitives

/// Append-only little-endian encoder.
struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Enc {
        Enc(Vec::new())
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked little-endian decoder: every read validates the
/// remaining length first, so truncation and corrupt length fields
/// surface as errors, never slice panics.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(len)?).into_owned())
    }

    /// Reject trailing garbage — catches desynced peers early.
    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after payload", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---------------------------------------------------------- domain types

fn put_matrix(e: &mut Enc, m: &Matrix) {
    e.u32(m.rows as u32);
    e.u32(m.cols as u32);
    // one up-front reservation: a gigabyte-scale gram must not be built
    // through doubling reallocations that memcpy the whole buffer
    e.0.reserve(m.data.len() * 4);
    for &v in &m.data {
        e.0.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_matrix(d: &mut Dec) -> Result<Matrix> {
    let rows = d.u32()? as usize;
    let cols = d.u32()? as usize;
    // overflow-proof size check before any allocation
    let bytes = rows.checked_mul(cols).and_then(|n| n.checked_mul(4));
    let Some(bytes) = bytes.filter(|&b| b <= d.buf.len() - d.pos) else {
        bail!("matrix {rows}x{cols} larger than remaining payload");
    };
    let raw = d.take(bytes)?;
    let data = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Matrix::from_vec(rows, cols, data))
}

fn put_target(e: &mut Enc, t: SparsityTarget) {
    match t {
        SparsityTarget::Unstructured(s) => {
            e.u8(0);
            e.f64(s);
        }
        SparsityTarget::NM { n, m } => {
            e.u8(1);
            e.u32(n as u32);
            e.u32(m as u32);
        }
    }
}

fn get_target(d: &mut Dec) -> Result<SparsityTarget> {
    match d.u8()? {
        0 => Ok(SparsityTarget::Unstructured(d.f64()?)),
        1 => Ok(SparsityTarget::NM { n: d.u32()? as usize, m: d.u32()? as usize }),
        k => bail!("unknown sparsity-target kind {k}"),
    }
}

fn put_alps(e: &mut Enc, c: &AlpsConfig) {
    e.f32(c.rho0);
    e.u32(c.update_every as u32);
    e.f32(c.rho_factors.0);
    e.f32(c.rho_factors.1);
    e.f32(c.rho_factors.2);
    e.f64(c.support_bands.0);
    e.f64(c.support_bands.1);
    e.u32(c.max_iters as u32);
    e.u32(c.pcg_iters as u32);
    e.u8(c.diag_scaling as u8);
    e.f32(c.damp);
}

fn get_alps(d: &mut Dec) -> Result<AlpsConfig> {
    Ok(AlpsConfig {
        rho0: d.f32()?,
        update_every: d.u32()? as usize,
        rho_factors: (d.f32()?, d.f32()?, d.f32()?),
        support_bands: (d.f64()?, d.f64()?),
        max_iters: d.u32()? as usize,
        pcg_iters: d.u32()? as usize,
        diag_scaling: d.u8()? != 0,
        damp: d.f32()?,
    })
}

fn put_spec(e: &mut Enc, spec: &MethodSpec) {
    match spec {
        MethodSpec::Magnitude => e.u8(0),
        MethodSpec::Wanda => e.u8(1),
        MethodSpec::SparseGpt(c) => {
            e.u8(2);
            e.u32(c.block_size as u32);
            e.f32(c.percdamp);
        }
        MethodSpec::DsNoT(c) => {
            e.u8(3);
            e.u32(c.max_cycles as u32);
            e.f64(c.min_gain);
        }
        MethodSpec::Alps(c) => {
            e.u8(4);
            put_alps(e, c);
        }
        MethodSpec::AlpsStructured(c) => {
            e.u8(5);
            put_alps(e, c);
        }
    }
}

fn get_spec(d: &mut Dec) -> Result<MethodSpec> {
    Ok(match d.u8()? {
        0 => MethodSpec::Magnitude,
        1 => MethodSpec::Wanda,
        2 => MethodSpec::SparseGpt(SparseGptConfig {
            block_size: d.u32()? as usize,
            percdamp: d.f32()?,
        }),
        3 => MethodSpec::DsNoT(DsNoTConfig {
            max_cycles: d.u32()? as usize,
            min_gain: d.f64()?,
        }),
        4 => MethodSpec::Alps(get_alps(d)?),
        5 => MethodSpec::AlpsStructured(get_alps(d)?),
        k => bail!("unknown method-spec kind {k}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn specimen_specs() -> Vec<MethodSpec> {
        vec![
            MethodSpec::Magnitude,
            MethodSpec::Wanda,
            MethodSpec::SparseGpt(SparseGptConfig { block_size: 48, percdamp: 0.03 }),
            MethodSpec::DsNoT(DsNoTConfig { max_cycles: 17, min_gain: 1e-7 }),
            MethodSpec::Alps(AlpsConfig { rho0: 0.25, max_iters: 123, ..Default::default() }),
            MethodSpec::AlpsStructured(AlpsConfig { pcg_iters: 3, ..Default::default() }),
        ]
    }

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn request_roundtrips_bit_exact() {
        let mut rng = Rng::new(1);
        for (i, spec) in specimen_specs().into_iter().enumerate() {
            let what = Matrix::randn(12, 6, &mut rng);
            let h = Matrix::randn(12, 12, &mut rng);
            let target = if i % 2 == 0 {
                SparsityTarget::Unstructured(0.65)
            } else {
                SparsityTarget::NM { n: 2, m: 4 }
            };
            let req = SolveRequest {
                job: 41 + i as u64,
                target,
                spec: spec.clone(),
                what: what.clone(),
                calib: Calib::Gram(h.clone()),
            };
            let back = SolveRequest::decode(&req.encode()).unwrap();
            assert_eq!(back.job, 41 + i as u64);
            assert_eq!(back.target, target);
            assert_eq!(back.spec, spec);
            // bit-exact matrices: compare the raw f32 bit patterns
            assert_eq!(bits(&back.what), bits(&what));
            let Calib::Gram(back_h) = back.calib else {
                panic!("calib kind changed in transit")
            };
            assert_eq!(bits(&back_h), bits(&h));
        }
    }

    #[test]
    fn activation_request_roundtrips_bit_exact() {
        let mut rng = Rng::new(4);
        let what = Matrix::randn(12, 6, &mut rng);
        let x = Matrix::randn(8, 12, &mut rng);
        let req = SolveRequest {
            job: 9,
            target: SparsityTarget::Unstructured(0.7),
            spec: MethodSpec::Alps(AlpsConfig::default()),
            what: what.clone(),
            calib: Calib::Activations(x.clone()),
        };
        let back = SolveRequest::decode(&req.encode()).unwrap();
        assert_eq!(bits(&back.what), bits(&what));
        let Calib::Activations(back_x) = back.calib else {
            panic!("calib kind changed in transit")
        };
        assert_eq!(bits(&back_x), bits(&x));
    }

    #[test]
    fn activation_payload_smaller_than_gram_for_wide_layers() {
        // the whole point of shipping activations: when the calibration
        // row count is below n_in, X [n, n_in] beats H [n_in, n_in]
        let mut rng = Rng::new(5);
        let (n, n_in, n_out) = (16, 64, 8);
        let what = Matrix::randn(n_in, n_out, &mut rng);
        let x = Matrix::randn(n, n_in, &mut rng);
        let h = crate::linalg::matmul::gram(&x);
        let spec = MethodSpec::Wanda;
        let t = SparsityTarget::Unstructured(0.5);
        let by_gram = encode_solve(0, t, &spec, &what, CalibRef::Gram(&h)).len();
        let by_acts = encode_solve(0, t, &spec, &what, CalibRef::Activations(&x)).len();
        assert!(
            by_acts < by_gram,
            "activations {by_acts}B should undercut gram {by_gram}B"
        );
    }

    #[test]
    fn response_roundtrips() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 4, &mut rng);
        let resp =
            SolveResponse { job: 7, secs: 0.125, admm_iters: 42, w: w.clone() };
        let back = SolveResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back.job, 7);
        assert_eq!(back.secs, 0.125);
        assert_eq!(back.admm_iters, 42);
        assert_eq!(back.w, w);
    }

    #[test]
    fn error_payload_roundtrips() {
        let buf = encode_error(3, "structured ALPS does not support N:M targets");
        let (job, msg) = decode_error(&buf).unwrap();
        assert_eq!(job, 3);
        assert!(msg.contains("N:M"));
    }

    #[test]
    fn heartbeat_roundtrips() {
        let hb = Heartbeat { job: 11, admm_iter: 250, elapsed_ms: 1234 };
        assert_eq!(decode_heartbeat(&encode_heartbeat(hb)).unwrap(), hb);
    }

    #[test]
    fn register_roundtrips_and_rejects_empty_address() {
        let buf = encode_register("worker-7.internal:7979");
        assert_eq!(decode_register(&buf).unwrap(), "worker-7.internal:7979");
        // an empty advertised address can never be dialed back
        let err = decode_register(&encode_register("")).unwrap_err().to_string();
        assert!(err.contains("malformed frame"), "{err}");
        assert!(err.contains("empty worker address"), "{err}");
    }

    /// Every strict prefix of every payload type must decode to an error
    /// (`malformed frame`), never panic — the per-field regression sweep
    /// for the truncation-hardening guarantee.
    #[test]
    fn every_truncation_of_every_payload_errors() {
        let mut rng = Rng::new(3);
        let solve_gram = SolveRequest {
            job: 1,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Wanda,
            what: Matrix::randn(4, 4, &mut rng),
            calib: Calib::Gram(Matrix::randn(4, 4, &mut rng)),
        }
        .encode();
        let solve_acts = SolveRequest {
            job: 2,
            target: SparsityTarget::NM { n: 2, m: 4 },
            spec: MethodSpec::Alps(AlpsConfig::default()),
            what: Matrix::randn(4, 2, &mut rng),
            calib: Calib::Activations(Matrix::randn(3, 4, &mut rng)),
        }
        .encode();
        let response = SolveResponse {
            job: 3,
            secs: 0.5,
            admm_iters: 9,
            w: Matrix::randn(4, 2, &mut rng),
        }
        .encode();
        let error = encode_error(4, "boom");
        let heartbeat =
            encode_heartbeat(Heartbeat { job: 5, admm_iter: 6, elapsed_ms: 7 });
        let register = encode_register("10.0.0.7:7979");

        for (name, buf) in [
            ("solve/gram", &solve_gram),
            ("solve/acts", &solve_acts),
            ("response", &response),
            ("error", &error),
            ("heartbeat", &heartbeat),
            ("register", &register),
        ] {
            for cut in 0..buf.len() {
                let err = match name {
                    "response" => SolveResponse::decode(&buf[..cut]).err(),
                    "error" => decode_error(&buf[..cut]).err(),
                    "heartbeat" => decode_heartbeat(&buf[..cut]).err(),
                    "register" => decode_register(&buf[..cut]).err(),
                    _ => SolveRequest::decode(&buf[..cut]).err(),
                };
                let err = err.unwrap_or_else(|| {
                    panic!("{name}: truncation at {cut} decoded cleanly")
                });
                assert!(
                    err.to_string().contains("malformed frame"),
                    "{name} cut {cut}: {err}"
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_and_oversized_headers_rejected() {
        let mut rng = Rng::new(6);
        let req = SolveRequest {
            job: 1,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Wanda,
            what: Matrix::randn(4, 4, &mut rng),
            calib: Calib::Gram(Matrix::randn(4, 4, &mut rng)),
        };
        // trailing garbage rejected on every payload type
        let with_junk = |mut v: Vec<u8>| {
            v.push(0);
            v
        };
        assert!(SolveRequest::decode(&with_junk(req.encode())).is_err());
        let resp =
            SolveResponse { job: 1, secs: 0.0, admm_iters: 0, w: Matrix::zeros(2, 2) };
        assert!(SolveResponse::decode(&with_junk(resp.encode())).is_err());
        assert!(decode_error(&with_junk(encode_error(1, "x"))).is_err());
        let hb = Heartbeat { job: 1, admm_iter: 0, elapsed_ms: 0 };
        assert!(decode_heartbeat(&with_junk(encode_heartbeat(hb))).is_err());
        assert!(decode_register(&with_junk(encode_register("w:1"))).is_err());
        // oversized matrix header rejected before allocation (u32::MAX
        // rows/cols would overflow rows*cols*4 without the checked_mul)
        let mut e = Enc::new();
        e.u64(1);
        put_target(&mut e, SparsityTarget::Unstructured(0.5));
        put_spec(&mut e, &MethodSpec::Wanda);
        e.u32(u32::MAX);
        e.u32(u32::MAX);
        let err = SolveRequest::decode(&e.0).unwrap_err().to_string();
        assert!(err.contains("larger than remaining"), "{err}");
        // unknown calibration kind rejected
        let mut e = Enc::new();
        e.u64(1);
        put_target(&mut e, SparsityTarget::Unstructured(0.5));
        put_spec(&mut e, &MethodSpec::Wanda);
        put_matrix(&mut e, &Matrix::zeros(2, 2));
        e.u8(9);
        let err = SolveRequest::decode(&e.0).unwrap_err().to_string();
        assert!(err.contains("calibration kind"), "{err}");
    }

    #[test]
    fn rebuilt_problem_matches_local_construction() {
        use crate::pruning::testutil::random_problem;
        let p = random_problem(10, 5, 40, 9);
        let req = SolveRequest {
            job: 0,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Magnitude,
            what: p.what.clone(),
            calib: Calib::Gram(p.h.clone()),
        };
        let back = SolveRequest::decode(&req.encode()).unwrap();
        let q = back.problem().unwrap();
        // the derived quantities are recomputed bit-identically
        assert_eq!(q.g, p.g);
        assert_eq!(q.denom, p.denom);
    }

    #[test]
    fn shipped_activations_rebuild_the_same_gram() {
        // worker-side gram computation must land on the exact bits the
        // coordinator's own `gram(x)` produced — same kernel, same input
        use crate::linalg::matmul::gram;
        let mut rng = Rng::new(10);
        let x = Matrix::randn(20, 12, &mut rng);
        let what = Matrix::randn(12, 5, &mut rng);
        let local = LayerProblem::from_gram(gram(&x), what.clone()).unwrap();
        let req = SolveRequest {
            job: 0,
            target: SparsityTarget::Unstructured(0.5),
            spec: MethodSpec::Magnitude,
            what,
            calib: Calib::Activations(x),
        };
        let remote = SolveRequest::decode(&req.encode()).unwrap().problem().unwrap();
        assert_eq!(bits(&remote.h), bits(&local.h));
        assert_eq!(bits(&remote.g), bits(&local.g));
        assert_eq!(remote.denom, local.denom);
    }
}
