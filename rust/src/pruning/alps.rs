//! ALPS — the paper's contribution: ADMM (Algorithm 1) with the eq.-28
//! rho-update scheme, followed by PCG refinement (Algorithm 2) on the
//! stabilized support. This module is the *native* path (pure rust); the
//! runtime path executes the identical math from AOT HLO artifacts
//! (`runtime::executor`) — integration tests pin the two against each other.

use super::projection;
use super::{LayerProblem, PruneMethod};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::linalg::solve::pcg_support;
use crate::linalg::{Matrix, SymEig};
use anyhow::Result;

/// ALPS pruner (ADMM + rho scheme + PCG post-processing).
#[derive(Default)]
pub struct Alps {
    pub cfg: AlpsConfig,
}

/// Diagnostics from one ALPS solve.
#[derive(Debug, Clone)]
pub struct AlpsTrace {
    pub admm_iters: usize,
    pub final_rho: f32,
    pub support_changes: Vec<usize>,
    /// ||W - D||_F per rho-update checkpoint (Theorem 1 residual).
    pub primal_gaps: Vec<f64>,
    pub pcg_iters: usize,
}

/// B.1 preprocessing: E = diag(H)^{-1/2}; work in W' = E^{-1} W where the
/// scaled gram E H E has unit diagonal.
pub struct DiagScaling {
    pub e: Vec<f32>, // E diagonal entries
}

impl DiagScaling {
    pub fn from_gram(h: &Matrix, damp: f32) -> (Self, Matrix) {
        let n = h.rows;
        let mean_diag: f32 = h.diag().iter().sum::<f32>() / n as f32;
        let floor = (damp * mean_diag).max(1e-12);
        let e: Vec<f32> = (0..n)
            .map(|i| 1.0 / (h.at(i, i) + floor).sqrt())
            .collect();
        let mut hs = h.clone();
        for r in 0..n {
            for c in 0..n {
                *hs.at_mut(r, c) *= e[r] * e[c];
            }
            // damping keeps degenerate grams positive definite
            *hs.at_mut(r, r) += damp;
        }
        (DiagScaling { e }, hs)
    }

    /// W' = E^{-1} W (scale rows by 1/e).
    pub fn to_scaled(&self, w: &Matrix) -> Matrix {
        let mut out = w.clone();
        for (r, &er) in self.e.iter().enumerate() {
            out.scale_row(r, 1.0 / er);
        }
        out
    }

    /// W = E W' (scale rows by e).
    pub fn to_unscaled(&self, w: &Matrix) -> Matrix {
        let mut out = w.clone();
        for (r, &er) in self.e.iter().enumerate() {
            out.scale_row(r, er);
        }
        out
    }

    /// G' = E (H What)   (the scaled-problem right-hand side).
    pub fn scale_g(&self, g: &Matrix) -> Matrix {
        self.to_unscaled(g) // same operation: multiply rows by e
    }
}

/// Eq. 28 rho update given the support change s_t and budget k.
pub fn rho_update(rho: f32, s_t: usize, k: usize, cfg: &AlpsConfig) -> f32 {
    let (f_big, f_mid, f_small) = cfg.rho_factors;
    let (band_big, band_mid) = cfg.support_bands;
    if (s_t as f64) >= band_big * k as f64 {
        rho * f_big
    } else if (s_t as f64) >= band_mid * k as f64 {
        rho * f_mid
    } else if s_t >= 1 {
        rho * f_small
    } else {
        rho
    }
}

impl Alps {
    pub fn with_config(cfg: AlpsConfig) -> Self {
        Alps { cfg }
    }

    /// Run ALPS, returning the pruned weights and diagnostics.
    pub fn prune_traced(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<(Matrix, AlpsTrace)> {
        self.prune_traced_observed(problem, target, None)
    }

    /// [`Alps::prune_traced`] with a live iteration counter: after each
    /// ADMM iteration the count is stored into `progress` (relaxed — it
    /// is a monitoring side channel, e.g. the distributed worker's
    /// heartbeat frames, and never feeds back into the solve).
    pub fn prune_traced_observed(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
        progress: Option<&std::sync::atomic::AtomicU64>,
    ) -> Result<(Matrix, AlpsTrace)> {
        let cfg = &self.cfg;
        let n_in = problem.n_in();
        let n_out = problem.n_out();
        let k = target.keep_count(n_in, n_out);

        // ---- B.1 preprocessing
        let (scaling, hs) = if cfg.diag_scaling {
            DiagScaling::from_gram(&problem.h, cfg.damp)
        } else {
            (
                DiagScaling { e: vec![1.0; n_in] },
                {
                    let mut h = problem.h.clone();
                    let mean_diag: f32 = h.diag().iter().sum::<f32>() / n_in as f32;
                    for i in 0..n_in {
                        *h.at_mut(i, i) += cfg.damp * mean_diag;
                    }
                    h
                },
            )
        };
        let gs = scaling.scale_g(&problem.g);
        let whats = scaling.to_scaled(&problem.what);

        // ---- cached eigendecomposition of the scaled gram
        let eig = SymEig::new(&hs)?;

        // ---- ADMM loop (Algorithm 1)
        let mut d = whats.clone();
        let mut v = Matrix::zeros(n_in, n_out);
        let mut rho = cfg.rho0;
        let mut t = 0usize;
        let mut prev_supp = d.support_mask();
        let mut trace = AlpsTrace {
            admm_iters: 0,
            final_rho: rho,
            support_changes: Vec::new(),
            primal_gaps: Vec::new(),
            pcg_iters: 0,
        };
        let mut w = whats.clone();

        while t < cfg.max_iters {
            for _ in 0..cfg.update_every {
                // W-update: (H + rho I)^{-1} (G - V + rho D)
                let mut b = gs.sub(&v);
                b.axpy(rho, &d);
                w = eig.ridge_solve(rho, &b);
                // D-update: project W + V/rho
                let mut z = w.clone();
                z.axpy(1.0 / rho, &v);
                d = match target {
                    SparsityTarget::Unstructured(_) => projection::topk_project(&z, k),
                    SparsityTarget::NM { n, m } => projection::nm_project(&z, n, m),
                };
                // V-update
                let mut wd = w.sub(&d);
                wd = wd.scale(rho);
                v = v.add(&wd);
                t += 1;
                if let Some(p) = progress {
                    p.store(t as u64, std::sync::atomic::Ordering::Relaxed);
                }
            }
            let supp = d.support_mask();
            let s_t = supp
                .data
                .iter()
                .zip(&prev_supp.data)
                .filter(|(a, b)| a != b)
                .count();
            prev_supp = supp;
            trace.support_changes.push(s_t);
            trace.primal_gaps.push(w.sub(&d).fro_norm() as f64);
            if s_t == 0 {
                break;
            }
            rho = rho_update(rho, s_t, k, cfg);
        }
        trace.admm_iters = t;
        trace.final_rho = rho;

        // ---- PCG refinement (Algorithm 2) on the frozen support
        let mask = d.support_mask();
        let (w_refined, info) =
            pcg_support(&hs, &gs, &d, &mask, cfg.pcg_iters, 1e-12);
        trace.pcg_iters = info.iters;

        Ok((scaling.to_unscaled(&w_refined), trace))
    }
}

impl PruneMethod for Alps {
    fn name(&self) -> &'static str {
        "alps"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        Ok(self.prune_traced(problem, target)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::magnitude::MagnitudePruning;
    use crate::pruning::sparsegpt::SparseGpt;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::{check_target, wanda::Wanda};

    #[test]
    fn respects_budget() {
        let p = random_problem(24, 12, 90, 0);
        let t = SparsityTarget::Unstructured(0.7);
        let w = Alps::default().prune(&p, t).unwrap();
        assert!(w.nnz() <= t.keep_count(24, 12));
    }

    #[test]
    fn respects_nm_pattern() {
        let p = random_problem(16, 8, 64, 1);
        let t = SparsityTarget::NM { n: 2, m: 4 };
        let w = Alps::default().prune(&p, t).unwrap();
        assert!(check_target(&w, t));
    }

    #[test]
    fn beats_all_baselines_at_high_sparsity() {
        // the paper's headline: ALPS wins, gap widens at high sparsity
        let p = random_problem(32, 16, 128, 2);
        let t = SparsityTarget::Unstructured(0.7);
        let e_alps = p.rel_error(&Alps::default().prune(&p, t).unwrap());
        let e_mp = p.rel_error(&MagnitudePruning.prune(&p, t).unwrap());
        let e_wanda = p.rel_error(&Wanda.prune(&p, t).unwrap());
        let e_sg = p.rel_error(&SparseGpt::default().prune(&p, t).unwrap());
        assert!(e_alps < e_mp, "alps {e_alps} !< mp {e_mp}");
        assert!(e_alps < e_wanda, "alps {e_alps} !< wanda {e_wanda}");
        assert!(e_alps < e_sg * 1.05, "alps {e_alps} !< sparsegpt {e_sg}");
    }

    #[test]
    fn rho_update_bands() {
        let cfg = AlpsConfig::default();
        let k = 1000;
        assert!((rho_update(1.0, 200, k, &cfg) - 1.3).abs() < 1e-6);
        assert!((rho_update(1.0, 50, k, &cfg) - 1.2).abs() < 1e-6);
        assert!((rho_update(1.0, 2, k, &cfg) - 1.1).abs() < 1e-6);
        assert!((rho_update(1.0, 0, k, &cfg) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn terminates_with_stable_support() {
        let p = random_problem(20, 10, 70, 3);
        let (_, trace) = Alps::default()
            .prune_traced(&p, SparsityTarget::Unstructured(0.6))
            .unwrap();
        assert!(trace.admm_iters < AlpsConfig::default().max_iters);
        assert_eq!(*trace.support_changes.last().unwrap(), 0);
    }

    #[test]
    fn theorem1_primal_gap_shrinks() {
        let p = random_problem(20, 10, 70, 4);
        let (_, trace) = Alps::default()
            .prune_traced(&p, SparsityTarget::Unstructured(0.5))
            .unwrap();
        let gaps = &trace.primal_gaps;
        assert!(gaps.len() >= 2);
        // final gap well below the initial gap (W(t) -> D(t))
        assert!(
            gaps.last().unwrap() < &(0.5 * gaps[0] + 1e-6),
            "gaps: {gaps:?}"
        );
    }

    #[test]
    fn scaling_roundtrip() {
        let p = random_problem(10, 5, 40, 5);
        let (s, _) = DiagScaling::from_gram(&p.h, 0.01);
        let w = p.what.clone();
        let back = s.to_unscaled(&s.to_scaled(&w));
        assert!(back.max_abs_diff(&w) < 1e-4);
    }

    #[test]
    fn scaled_gram_unit_diagonal() {
        let p = random_problem(10, 5, 40, 6);
        let (_, hs) = DiagScaling::from_gram(&p.h, 0.0);
        for i in 0..10 {
            assert!((hs.at(i, i) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn no_scaling_variant_still_works() {
        let p = random_problem(16, 8, 60, 7);
        let alps = Alps::with_config(AlpsConfig { diag_scaling: false, ..Default::default() });
        let t = SparsityTarget::Unstructured(0.5);
        let w = alps.prune(&p, t).unwrap();
        assert!(w.nnz() <= t.keep_count(16, 8));
        assert!(p.rel_error(&w) < 1.0);
    }

    #[test]
    fn extreme_sparsity_ok() {
        let p = random_problem(16, 8, 60, 8);
        let w = Alps::default()
            .prune(&p, SparsityTarget::Unstructured(0.95))
            .unwrap();
        assert!(w.nnz() >= 1);
        assert!(w.nnz() <= SparsityTarget::Unstructured(0.95).keep_count(16, 8));
    }
}
