//! One-shot layer-wise pruning: methods, engines, and the session API.
//!
//! Every method consumes a [`LayerProblem`] (the dense weights plus the
//! calibration gram matrix) and a [`SparsityTarget`], and returns a sparse
//! weight matrix. ALPS is the paper's contribution; MP / Wanda / SparseGPT /
//! DSnoT are the competing baselines reimplemented from their papers;
//! `backsolve` is the exact support-restricted solver used by Table 1.
//!
//! The pipeline layers on top of the methods:
//! * [`MethodSpec`] — a typed method selector carrying each method's
//!   hyperparameters ([`crate::config::AlpsConfig`], [`SparseGptConfig`],
//!   [`DsNoTConfig`]), replacing the old stringly `method_by_name` dispatch.
//!   `MethodSpec::parse("alps")` for CLI input, `spec.build()` for a
//!   [`PruneMethod`] instance, `MethodSpec::all()` for the paper's
//!   five-method comparison set.
//! * [`engine::Engine`] — *where* a layer problem is solved:
//!   [`engine::NativeEngine`] fans the block's matrices across a thread
//!   pool, [`engine::HloEngine`] routes ALPS through the AOT HLO
//!   artifacts, and [`crate::coordinator::ShardedEngine`] fans them
//!   across a TCP worker pool with bit-identical results.
//! * [`session::PruneSession`] — the block-by-block pipeline: builder
//!   configuration, streaming [`session::ProgressEvent`]s, and per-block
//!   checkpoint/resume. See `session.rs` for the architecture.
//! * Distribution: [`wire`] (the layer-solve frame codec, protocol v2:
//!   calibration ships as a gram or as raw activations for worker-side
//!   gram computation, plus worker keepalive heartbeats), [`worker`]
//!   (the `alps worker` endpoint hosting `NativeEngine` behind that
//!   protocol, heartbeating while it solves), and [`status`] (a TCP
//!   endpoint streaming the session's progress snapshot with per-worker
//!   attribution and live heartbeat progress) — all built on the shared
//!   [`crate::net`] transport layer.
//! * Observability: the session dual-writes the [`crate::obs`] registry
//!   (`alps_prune_layers_total`, per-method solve-time histograms, the
//!   current-block gauge) and stamps every [`session::ProgressEvent`]
//!   with wall seconds since the run started; the status endpoint and
//!   the worker port both answer `GET /metrics` with the Prometheus
//!   exposition, and `--trace-out` streams spans/events as JSONL.
//!
//! The old `method_by_name` / `all_methods` free functions and the
//! coordinator's `PruneEngine` enum remain as deprecated shims for one
//! release.

pub mod alps;
pub mod backsolve;
pub mod dsnot;
pub mod engine;
pub mod magnitude;
pub mod projection;
pub mod quantize;
pub mod session;
pub mod sparsegpt;
pub mod status;
pub mod structured;
pub mod wanda;
pub mod wire;
pub mod worker;

pub use engine::{Engine, HloEngine, LayerJob, LayerResult, NativeEngine};
pub use session::{ProgressEvent, PruneSession, PruneSessionBuilder};
pub use status::{StatusBoard, StatusServer};
pub use worker::{register_with_coordinator, Worker, WorkerConfig};

use crate::config::{AlpsConfig, DsNoTConfig, SparseGptConfig, SparsityTarget};
use crate::linalg::matmul::{gram, matmul};
use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// The layer-wise pruning problem (1): weights + calibration statistics.
///
/// Stores H = X^T X and G = H What rather than X itself — the
/// reconstruction objective depends on X only through H:
///   ||X What - X W||_F^2 = tr((What - W)^T H (What - W)).
///
/// The raw activations `x` ride along as an optional shared handle when
/// the owner opts in via [`LayerProblem::attach_activations`] (the
/// session pipeline does, sharing one tap's rows across several layers
/// at zero copy). Distribution uses them: shipping X `[n, n_in]` instead
/// of H `[n_in, n_in]` shrinks a wide layer's wire payload whenever
/// `n < n_in`, with the worker rebuilding the same H from the same bits.
/// Retention is opt-in precisely because it pins X for the problem's
/// lifetime — paths that only need H should not pay that memory.
#[derive(Clone)]
pub struct LayerProblem {
    /// Dense weights What, [n_in, n_out].
    pub what: Matrix,
    /// Gram matrix H = X^T X, [n_in, n_in].
    pub h: Matrix,
    /// G = H @ What, [n_in, n_out] (cached).
    pub g: Matrix,
    /// tr(What^T H What) = ||X What||_F^2 (cached normalizer).
    pub denom: f64,
    /// Calibration activations X [n, n_in] when the caller attached them
    /// (shared, so wq/wk/wv carry the same rows without copies). `None`
    /// unless [`LayerProblem::attach_activations`] was called.
    pub x: Option<std::sync::Arc<Matrix>>,
}

impl LayerProblem {
    /// Build from explicit activations X and dense weights. X is *not*
    /// retained (most callers only ever need H); owners that want
    /// activation-shipping distribution attach their copy afterwards via
    /// [`LayerProblem::attach_activations`].
    pub fn from_activations(x: &Matrix, what: &Matrix) -> Result<Self> {
        if x.cols != what.rows {
            bail!("activation dim {} != weight n_in {}", x.cols, what.rows);
        }
        let h = gram(x);
        Self::from_gram(h, what.clone())
    }

    /// Build from a precomputed gram matrix (the runtime path computes H on
    /// the PJRT device and hands it over here).
    pub fn from_gram(h: Matrix, what: Matrix) -> Result<Self> {
        if h.rows != h.cols || h.rows != what.rows {
            bail!(
                "gram {}x{} incompatible with weights {}x{}",
                h.rows, h.cols, what.rows, what.cols
            );
        }
        let g = matmul(&h, &what);
        let denom = what.dot(&g).max(1e-30);
        Ok(LayerProblem { what, h, g, denom, x: None })
    }

    /// Retain a shared handle to the calibration activations behind this
    /// problem's gram. The caller asserts `gram(x) == h` bit-for-bit (the
    /// session computes H from exactly these rows); the dimension check
    /// here catches wiring mistakes.
    pub fn attach_activations(&mut self, x: std::sync::Arc<Matrix>) -> Result<()> {
        if x.cols != self.what.rows {
            bail!("activation dim {} != weight n_in {}", x.cols, self.what.rows);
        }
        self.x = Some(x);
        Ok(())
    }

    pub fn n_in(&self) -> usize {
        self.what.rows
    }

    pub fn n_out(&self) -> usize {
        self.what.cols
    }

    /// Relative reconstruction error ||X What - X W||^2 / ||X What||^2,
    /// computed from H (no X needed).
    pub fn rel_error(&self, w: &Matrix) -> f64 {
        let delta = self.what.sub(w);
        let hd = matmul(&self.h, &delta);
        (delta.dot(&hd) / self.denom).max(0.0)
    }

    /// Column norms of X (sqrt of diag(H)) — the Wanda activation statistic.
    pub fn x_col_norms(&self) -> Vec<f32> {
        self.h.diag().iter().map(|d| d.max(0.0).sqrt()).collect()
    }
}

/// A one-shot pruning method.
pub trait PruneMethod {
    /// Short identifier used by the CLI and bench tables.
    fn name(&self) -> &'static str;
    /// Prune the layer to the target sparsity.
    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix>;
}

/// A typed method selector carrying the method's hyperparameters.
///
/// This replaces string dispatch: the spec is `Clone + Send + Sync` plain
/// data, so engines can rebuild the method per worker thread, and callers
/// can sweep solver hyperparameters (SparseGPT block size, DSnoT cycles,
/// the full [`AlpsConfig`]) per run instead of being locked to defaults.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// Magnitude pruning (MP) — no hyperparameters.
    Magnitude,
    /// Wanda — no hyperparameters.
    Wanda,
    /// SparseGPT with its mask-selection block size and damping.
    SparseGpt(SparseGptConfig),
    /// DSnoT grow/prune refinement on a Wanda mask.
    DsNoT(DsNoTConfig),
    /// ALPS (the paper's method) with the full ADMM + PCG config.
    Alps(AlpsConfig),
    /// Row-structured ALPS (input-neuron pruning; unstructured targets only).
    AlpsStructured(AlpsConfig),
}

impl MethodSpec {
    /// Parse a CLI method name into a spec with default hyperparameters.
    pub fn parse(name: &str) -> Result<MethodSpec> {
        Ok(match name {
            "mp" | "magnitude" => MethodSpec::Magnitude,
            "wanda" => MethodSpec::Wanda,
            "sparsegpt" => MethodSpec::SparseGpt(SparseGptConfig::default()),
            "dsnot" => MethodSpec::DsNoT(DsNoTConfig::default()),
            "alps" => MethodSpec::Alps(AlpsConfig::default()),
            "alps-struct" => MethodSpec::AlpsStructured(AlpsConfig::default()),
            _ => bail!(
                "unknown method '{name}' (mp|wanda|sparsegpt|dsnot|alps|alps-struct)"
            ),
        })
    }

    /// Short identifier used by the CLI, reports, and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            MethodSpec::Magnitude => "mp",
            MethodSpec::Wanda => "wanda",
            MethodSpec::SparseGpt(_) => "sparsegpt",
            MethodSpec::DsNoT(_) => "dsnot",
            MethodSpec::Alps(_) => "alps",
            MethodSpec::AlpsStructured(_) => "alps-struct",
        }
    }

    /// Instantiate the method with this spec's hyperparameters.
    pub fn build(&self) -> Box<dyn PruneMethod> {
        match self {
            MethodSpec::Magnitude => Box::new(magnitude::MagnitudePruning),
            MethodSpec::Wanda => Box::new(wanda::Wanda),
            MethodSpec::SparseGpt(cfg) => {
                Box::new(sparsegpt::SparseGpt::with_config(cfg.clone()))
            }
            MethodSpec::DsNoT(cfg) => Box::new(dsnot::DsNoT::with_config(cfg.clone())),
            MethodSpec::Alps(cfg) => Box::new(alps::Alps::with_config(cfg.clone())),
            MethodSpec::AlpsStructured(cfg) => Box::new(structured::StructuredAlpsMethod(
                structured::StructuredAlps { cfg: cfg.clone() },
            )),
        }
    }

    /// Build and run the method in one call — the common case for
    /// single-layer experiments (benches, `alps layer`).
    pub fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        self.build().prune(problem, target)
    }

    /// The paper's five-method comparison set, in paper order
    /// (MP, Wanda, SparseGPT, DSnoT, ALPS), with default hyperparameters.
    pub fn all() -> Vec<MethodSpec> {
        vec![
            MethodSpec::Magnitude,
            MethodSpec::Wanda,
            MethodSpec::SparseGpt(SparseGptConfig::default()),
            MethodSpec::DsNoT(DsNoTConfig::default()),
            MethodSpec::Alps(AlpsConfig::default()),
        ]
    }
}

/// All registered methods in paper order (MP, Wanda, SparseGPT, DSnoT, ALPS).
#[deprecated(note = "use MethodSpec::all() and build() per spec")]
pub fn all_methods() -> Vec<Box<dyn PruneMethod>> {
    MethodSpec::all().iter().map(MethodSpec::build).collect()
}

/// Look up a method by CLI name.
#[deprecated(note = "use MethodSpec::parse(name)?.build()")]
pub fn method_by_name(name: &str) -> Result<Box<dyn PruneMethod>> {
    Ok(MethodSpec::parse(name)?.build())
}

/// Check a weight matrix satisfies the sparsity target.
pub fn check_target(w: &Matrix, target: SparsityTarget) -> bool {
    match target {
        SparsityTarget::Unstructured(_) => {
            w.nnz() <= target.keep_count(w.rows, w.cols)
        }
        SparsityTarget::NM { n, m } => {
            for c in 0..w.cols {
                for g0 in (0..w.rows).step_by(m) {
                    let nnz = (g0..(g0 + m).min(w.rows))
                        .filter(|&r| w.at(r, c) != 0.0)
                        .count();
                    if nnz > n {
                        return false;
                    }
                }
            }
            true
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// Random layer problem with a mildly anisotropic X (so methods
    /// differ). X is attached (moved, no copy) so activation-shipping
    /// tests find it on the problem, as session-built problems do.
    pub fn random_problem(n_in: usize, n_out: usize, rows: usize, seed: u64) -> LayerProblem {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::randn(rows, n_in, &mut rng);
        // anisotropy: scale feature columns by varying factors
        for c in 0..n_in {
            let s = 0.3 + 1.7 * ((c * 37 % n_in) as f32 / n_in as f32);
            for r in 0..rows {
                *x.at_mut(r, c) *= s;
            }
        }
        let what = Matrix::randn(n_in, n_out, &mut rng);
        let mut p = LayerProblem::from_activations(&x, &what).unwrap();
        p.attach_activations(std::sync::Arc::new(x)).unwrap();
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::random_problem;

    #[test]
    fn rel_error_zero_for_dense() {
        let p = random_problem(16, 8, 60, 0);
        assert!(p.rel_error(&p.what) < 1e-9);
    }

    #[test]
    fn rel_error_one_for_zero() {
        let p = random_problem(16, 8, 60, 1);
        let z = Matrix::zeros(16, 8);
        assert!((p.rel_error(&z) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn from_activations_validates_dims() {
        let x = Matrix::zeros(10, 4);
        let w = Matrix::zeros(5, 3);
        assert!(LayerProblem::from_activations(&x, &w).is_err());
    }

    #[test]
    fn registry_has_five_methods() {
        let specs = MethodSpec::all();
        let labels: Vec<&str> = specs.iter().map(MethodSpec::label).collect();
        assert_eq!(labels, vec!["mp", "wanda", "sparsegpt", "dsnot", "alps"]);
        // built methods agree with their spec labels
        for spec in &specs {
            assert_eq!(spec.build().name(), spec.label());
        }
    }

    #[test]
    fn method_spec_parse_roundtrip() {
        for name in ["mp", "wanda", "sparsegpt", "dsnot", "alps", "alps-struct"] {
            let spec = MethodSpec::parse(name).unwrap();
            assert_eq!(spec.label(), name);
        }
        assert_eq!(MethodSpec::parse("magnitude").unwrap(), MethodSpec::Magnitude);
        let err = MethodSpec::parse("???").unwrap_err().to_string();
        assert!(err.contains("unknown method"), "{err}");
        assert!(err.contains("alps"), "error should list valid names: {err}");
    }

    #[test]
    fn method_spec_carries_config() {
        let spec = MethodSpec::Alps(AlpsConfig { max_iters: 7, ..Default::default() });
        match &spec {
            MethodSpec::Alps(cfg) => assert_eq!(cfg.max_iters, 7),
            _ => unreachable!(),
        }
        // config participates in equality
        assert_ne!(spec, MethodSpec::Alps(AlpsConfig::default()));
        // and a DSnoT spec with zero cycles builds a method that degenerates
        // to Wanda (the configs really reach the solver)
        let p = testutil::random_problem(12, 6, 50, 9);
        let t = SparsityTarget::Unstructured(0.5);
        let w_frozen = MethodSpec::DsNoT(DsNoTConfig { max_cycles: 0, ..Default::default() })
            .build()
            .prune(&p, t)
            .unwrap();
        let w_wanda = MethodSpec::Wanda.build().prune(&p, t).unwrap();
        assert_eq!(w_frozen, w_wanda);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_lookup_shims_still_work() {
        assert!(method_by_name("alps").is_ok());
        assert!(method_by_name("magnitude").is_ok());
        assert!(method_by_name("???").is_err());
        assert_eq!(all_methods().len(), 5);
    }

    #[test]
    fn check_target_unstructured() {
        let mut w = Matrix::zeros(4, 4);
        w.data[0] = 1.0;
        w.data[5] = 1.0;
        assert!(check_target(&w, SparsityTarget::Unstructured(0.8)));
        assert!(!check_target(&w, SparsityTarget::Unstructured(0.95)));
    }

    #[test]
    fn check_target_nm() {
        let mut w = Matrix::zeros(4, 1);
        w.data[0] = 1.0;
        w.data[1] = 1.0;
        assert!(check_target(&w, SparsityTarget::NM { n: 2, m: 4 }));
        w.data[2] = 1.0;
        assert!(!check_target(&w, SparsityTarget::NM { n: 2, m: 4 }));
    }

    #[test]
    fn x_col_norms_positive() {
        let p = random_problem(12, 4, 50, 2);
        assert!(p.x_col_norms().iter().all(|&v| v > 0.0));
    }
}
