//! One-shot layer-wise pruning methods.
//!
//! Every method consumes a [`LayerProblem`] (the dense weights plus the
//! calibration gram matrix) and a [`SparsityTarget`], and returns a sparse
//! weight matrix. ALPS is the paper's contribution; MP / Wanda / SparseGPT /
//! DSnoT are the competing baselines reimplemented from their papers;
//! `backsolve` is the exact support-restricted solver used by Table 1.

pub mod alps;
pub mod backsolve;
pub mod dsnot;
pub mod magnitude;
pub mod projection;
pub mod quantize;
pub mod sparsegpt;
pub mod structured;
pub mod wanda;

use crate::config::SparsityTarget;
use crate::linalg::matmul::{gram, matmul};
use crate::linalg::Matrix;
use anyhow::{bail, Result};

/// The layer-wise pruning problem (1): weights + calibration statistics.
///
/// Stores H = X^T X and G = H What rather than X itself — the
/// reconstruction objective depends on X only through H:
///   ||X What - X W||_F^2 = tr((What - W)^T H (What - W)).
#[derive(Clone)]
pub struct LayerProblem {
    /// Dense weights What, [n_in, n_out].
    pub what: Matrix,
    /// Gram matrix H = X^T X, [n_in, n_in].
    pub h: Matrix,
    /// G = H @ What, [n_in, n_out] (cached).
    pub g: Matrix,
    /// tr(What^T H What) = ||X What||_F^2 (cached normalizer).
    pub denom: f64,
}

impl LayerProblem {
    /// Build from explicit activations X and dense weights.
    pub fn from_activations(x: &Matrix, what: &Matrix) -> Result<Self> {
        if x.cols != what.rows {
            bail!("activation dim {} != weight n_in {}", x.cols, what.rows);
        }
        let h = gram(x);
        Self::from_gram(h, what.clone())
    }

    /// Build from a precomputed gram matrix (the runtime path computes H on
    /// the PJRT device and hands it over here).
    pub fn from_gram(h: Matrix, what: Matrix) -> Result<Self> {
        if h.rows != h.cols || h.rows != what.rows {
            bail!("gram {}x{} incompatible with weights {}x{}", h.rows, h.cols, what.rows, what.cols);
        }
        let g = matmul(&h, &what);
        let denom = what.dot(&g).max(1e-30);
        Ok(LayerProblem { what, h, g, denom })
    }

    pub fn n_in(&self) -> usize {
        self.what.rows
    }

    pub fn n_out(&self) -> usize {
        self.what.cols
    }

    /// Relative reconstruction error ||X What - X W||^2 / ||X What||^2,
    /// computed from H (no X needed).
    pub fn rel_error(&self, w: &Matrix) -> f64 {
        let delta = self.what.sub(w);
        let hd = matmul(&self.h, &delta);
        (delta.dot(&hd) / self.denom).max(0.0)
    }

    /// Column norms of X (sqrt of diag(H)) — the Wanda activation statistic.
    pub fn x_col_norms(&self) -> Vec<f32> {
        self.h.diag().iter().map(|d| d.max(0.0).sqrt()).collect()
    }
}

/// A one-shot pruning method.
pub trait PruneMethod {
    /// Short identifier used by the CLI and bench tables.
    fn name(&self) -> &'static str;
    /// Prune the layer to the target sparsity.
    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix>;
}

/// All registered methods in paper order (MP, Wanda, SparseGPT, DSnoT, ALPS).
pub fn all_methods() -> Vec<Box<dyn PruneMethod>> {
    vec![
        Box::new(magnitude::MagnitudePruning),
        Box::new(wanda::Wanda),
        Box::new(sparsegpt::SparseGpt::default()),
        Box::new(dsnot::DsNoT::default()),
        Box::new(alps::Alps::default()),
    ]
}

/// Look up a method by CLI name.
pub fn method_by_name(name: &str) -> Result<Box<dyn PruneMethod>> {
    let m: Box<dyn PruneMethod> = match name {
        "mp" | "magnitude" => Box::new(magnitude::MagnitudePruning),
        "wanda" => Box::new(wanda::Wanda),
        "sparsegpt" => Box::new(sparsegpt::SparseGpt::default()),
        "dsnot" => Box::new(dsnot::DsNoT::default()),
        "alps" => Box::new(alps::Alps::default()),
        "alps-struct" => Box::new(structured::StructuredAlpsMethod(
            structured::StructuredAlps::default(),
        )),
        _ => bail!("unknown method '{name}' (mp|wanda|sparsegpt|dsnot|alps|alps-struct)"),
    };
    Ok(m)
}

/// Check a weight matrix satisfies the sparsity target.
pub fn check_target(w: &Matrix, target: SparsityTarget) -> bool {
    match target {
        SparsityTarget::Unstructured(_) => {
            w.nnz() <= target.keep_count(w.rows, w.cols)
        }
        SparsityTarget::NM { n, m } => {
            for c in 0..w.cols {
                for g0 in (0..w.rows).step_by(m) {
                    let nnz = (g0..(g0 + m).min(w.rows))
                        .filter(|&r| w.at(r, c) != 0.0)
                        .count();
                    if nnz > n {
                        return false;
                    }
                }
            }
            true
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// Random layer problem with a mildly anisotropic X (so methods differ).
    pub fn random_problem(n_in: usize, n_out: usize, rows: usize, seed: u64) -> LayerProblem {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::randn(rows, n_in, &mut rng);
        // anisotropy: scale feature columns by varying factors
        for c in 0..n_in {
            let s = 0.3 + 1.7 * ((c * 37 % n_in) as f32 / n_in as f32);
            for r in 0..rows {
                *x.at_mut(r, c) *= s;
            }
        }
        let what = Matrix::randn(n_in, n_out, &mut rng);
        LayerProblem::from_activations(&x, &what).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::random_problem;

    #[test]
    fn rel_error_zero_for_dense() {
        let p = random_problem(16, 8, 60, 0);
        assert!(p.rel_error(&p.what) < 1e-9);
    }

    #[test]
    fn rel_error_one_for_zero() {
        let p = random_problem(16, 8, 60, 1);
        let z = Matrix::zeros(16, 8);
        assert!((p.rel_error(&z) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn from_activations_validates_dims() {
        let x = Matrix::zeros(10, 4);
        let w = Matrix::zeros(5, 3);
        assert!(LayerProblem::from_activations(&x, &w).is_err());
    }

    #[test]
    fn registry_has_five_methods() {
        let ms = all_methods();
        let names: Vec<&str> = ms.iter().map(|m| m.name()).collect();
        assert_eq!(names, vec!["mp", "wanda", "sparsegpt", "dsnot", "alps"]);
    }

    #[test]
    fn method_lookup() {
        assert!(method_by_name("alps").is_ok());
        assert!(method_by_name("magnitude").is_ok());
        assert!(method_by_name("???").is_err());
    }

    #[test]
    fn check_target_unstructured() {
        let mut w = Matrix::zeros(4, 4);
        w.data[0] = 1.0;
        w.data[5] = 1.0;
        assert!(check_target(&w, SparsityTarget::Unstructured(0.8)));
        assert!(!check_target(&w, SparsityTarget::Unstructured(0.95)));
    }

    #[test]
    fn check_target_nm() {
        let mut w = Matrix::zeros(4, 1);
        w.data[0] = 1.0;
        w.data[1] = 1.0;
        assert!(check_target(&w, SparsityTarget::NM { n: 2, m: 4 }));
        w.data[2] = 1.0;
        assert!(!check_target(&w, SparsityTarget::NM { n: 2, m: 4 }));
    }

    #[test]
    fn x_col_norms_positive() {
        let p = random_problem(12, 4, 50, 2);
        assert!(p.x_col_norms().iter().all(|&v| v > 0.0));
    }
}
