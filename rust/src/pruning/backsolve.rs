//! Backsolve: exact support-restricted least squares (problem (6)) via
//! per-column dense Cholesky solves — the slow-but-optimal baseline of
//! Table 1 (right) that PCG is benchmarked against.

use super::LayerProblem;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::Matrix;
use anyhow::Result;

/// Solve min ||X What - X W||_F^2 s.t. supp(W) ⊆ supp(mask), exactly:
/// for every column j, invert the support submatrix H_SS and solve
/// H_SS w_S = g_S. This is the "direct matrix inversion (backsolve)"
/// approach of Sec. 3.3 — O(N_out) solves of size O(N_in).
pub fn solve_on_support(problem: &LayerProblem, mask: &Matrix) -> Result<Matrix> {
    solve_on_support_damped(problem, mask, 1e-6)
}

/// Backsolve with explicit diagonal damping (relative to mean diag).
pub fn solve_on_support_damped(
    problem: &LayerProblem,
    mask: &Matrix,
    damp_frac: f32,
) -> Result<Matrix> {
    let h = &problem.h;
    let g = &problem.g;
    let n_in = problem.n_in();
    let n_out = problem.n_out();
    assert_eq!((mask.rows, mask.cols), (n_in, n_out));

    let mean_diag: f32 = h.diag().iter().sum::<f32>() / n_in as f32;
    let damp = damp_frac * mean_diag;

    let mut w = Matrix::zeros(n_in, n_out);
    for j in 0..n_out {
        let support: Vec<usize> = (0..n_in).filter(|&i| mask.at(i, j) != 0.0).collect();
        let s = support.len();
        if s == 0 {
            continue;
        }
        let mut hs = Matrix::zeros(s, s);
        for (a, &i) in support.iter().enumerate() {
            for (b, &k) in support.iter().enumerate() {
                *hs.at_mut(a, b) = h.at(i, k);
            }
            *hs.at_mut(a, a) += damp;
        }
        let gs: Vec<f32> = support.iter().map(|&i| g.at(i, j)).collect();
        let ws = Cholesky::new(&hs)?.solve_vec(&gs);
        for (a, &i) in support.iter().enumerate() {
            *w.at_mut(i, j) = ws[a];
        }
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityTarget;
    use crate::linalg::solve::pcg_support;
    use crate::pruning::magnitude::MagnitudePruning;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::PruneMethod;

    #[test]
    fn optimal_on_full_support_recovers_dense() {
        let p = random_problem(12, 6, 50, 0);
        let mask = Matrix::from_vec(12, 6, vec![1.0; 72]);
        let w = solve_on_support(&p, &mask).unwrap();
        assert!(p.rel_error(&w) < 1e-6);
    }

    #[test]
    fn empty_support_gives_zero() {
        let p = random_problem(8, 4, 40, 1);
        let mask = Matrix::zeros(8, 4);
        let w = solve_on_support(&p, &mask).unwrap();
        assert_eq!(w.nnz(), 0);
    }

    #[test]
    fn improves_masked_magnitude_weights() {
        let p = random_problem(20, 10, 80, 2);
        let t = SparsityTarget::Unstructured(0.6);
        let w_mp = MagnitudePruning.prune(&p, t).unwrap();
        let w_opt = solve_on_support(&p, &w_mp.support_mask()).unwrap();
        assert!(p.rel_error(&w_opt) <= p.rel_error(&w_mp) + 1e-9);
    }

    #[test]
    fn is_optimal_among_same_support() {
        // PCG run to convergence must not beat the backsolve solution
        let p = random_problem(16, 8, 64, 3);
        let t = SparsityTarget::Unstructured(0.5);
        let mask = MagnitudePruning.prune(&p, t).unwrap().support_mask();
        let w_bs = solve_on_support_damped(&p, &mask, 0.0).unwrap();
        let (w_pcg, _) = pcg_support(&p.h, &p.g, &Matrix::zeros(16, 8), &mask, 500, 1e-12);
        assert!(p.rel_error(&w_bs) <= p.rel_error(&w_pcg) + 1e-6);
        // ... and PCG must come close
        assert!((p.rel_error(&w_pcg) - p.rel_error(&w_bs)).abs() < 1e-3);
    }

    #[test]
    fn support_respected() {
        let p = random_problem(10, 5, 40, 4);
        let t = SparsityTarget::Unstructured(0.7);
        let mask = MagnitudePruning.prune(&p, t).unwrap().support_mask();
        let w = solve_on_support(&p, &mask).unwrap();
        for i in 0..w.data.len() {
            if mask.data[i] == 0.0 {
                assert_eq!(w.data[i], 0.0);
            }
        }
    }
}
