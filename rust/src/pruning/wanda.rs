//! Wanda (Sun et al. 2023): prune by |W_ij| * ||X_:,i||_2 with per-output
//! comparison groups — no weight update, just a better importance score.

use super::projection;
use super::{LayerProblem, PruneMethod};
use crate::config::SparsityTarget;
use crate::linalg::Matrix;
use anyhow::Result;

/// Wanda: weights AND activations.
pub struct Wanda;

impl Wanda {
    /// Score matrix S_ij = |W_ij| * ||X_:,i||_2.
    pub fn scores(problem: &LayerProblem) -> Matrix {
        let norms = problem.x_col_norms();
        let w = &problem.what;
        let mut s = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let nr = norms[r];
            for c in 0..w.cols {
                *s.at_mut(r, c) = w.at(r, c).abs() * nr;
            }
        }
        s
    }
}

impl PruneMethod for Wanda {
    fn name(&self) -> &'static str {
        "wanda"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        let scores = Self::scores(problem);
        // Wanda's comparison group: weights feeding the same output
        Ok(projection::project_by_score(&problem.what, &scores, target, true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::pruning::check_target;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::LayerProblem;
    use crate::util::Rng;

    #[test]
    fn respects_budget() {
        let p = random_problem(16, 8, 64, 0);
        let t = SparsityTarget::Unstructured(0.6);
        let w = Wanda.prune(&p, t).unwrap();
        assert!(w.nnz() <= t.keep_count(16, 8) + 8); // per-column rounding
        assert!(check_target(&w, SparsityTarget::Unstructured(0.5)));
    }

    #[test]
    fn equals_mp_when_x_isotropic() {
        // if all feature norms are equal, Wanda's score reduces to |W| and
        // per-column selection matches per-column MP
        let mut rng = Rng::new(1);
        let n = 8;
        let x = Matrix::identity(n).scale(2.0); // all col norms = 2
        let what = Matrix::randn(n, 4, &mut rng);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let ww = Wanda.prune(&p, SparsityTarget::Unstructured(0.5)).unwrap();
        // per column, kept entries must be that column's top-|w| half
        for c in 0..4 {
            let col = what.col(c);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| col[b].abs().partial_cmp(&col[a].abs()).unwrap());
            for &r in order.iter().take(n / 2) {
                assert_ne!(ww.at(r, c), 0.0);
            }
        }
    }

    #[test]
    fn downweights_weak_features() {
        // a large weight on a near-dead input must be pruned before a
        // smaller weight on a strong input
        let mut x = Matrix::zeros(10, 2);
        for r in 0..10 {
            *x.at_mut(r, 0) = 5.0; // strong feature
            *x.at_mut(r, 1) = 0.01; // dead feature
        }
        let what = Matrix::from_vec(2, 1, vec![0.5, 3.0]);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let w = Wanda.prune(&p, SparsityTarget::Unstructured(0.5)).unwrap();
        assert_ne!(w.at(0, 0), 0.0, "strong-feature weight kept");
        assert_eq!(w.at(1, 0), 0.0, "dead-feature weight pruned");
    }

    #[test]
    fn nm_pattern() {
        let p = random_problem(16, 4, 64, 2);
        let t = SparsityTarget::NM { n: 2, m: 4 };
        let w = Wanda.prune(&p, t).unwrap();
        assert!(check_target(&w, t));
    }
}
