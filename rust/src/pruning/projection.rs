//! Projection operators: exact global top-k (P_k of eq. 4) and the N:M
//! group projection — the rust mirrors of the Layer-1 kernels.
//!
//! Every magnitude/score comparator here uses [`f32::total_cmp`]: a NaN
//! weight or calibration score (possible with degenerate Hessians) sorts
//! deterministically above every finite magnitude instead of panicking
//! inside `sort`/`select_nth` the way `partial_cmp().unwrap()` did.

use crate::config::SparsityTarget;
use crate::linalg::Matrix;
use anyhow::{ensure, Result};

/// Exact Euclidean projection onto {||W||_0 <= k}: keep the k
/// largest-magnitude entries (ties broken toward lower flat index, matching
/// the stable argsort in the HLO graph).
pub fn topk_project(w: &Matrix, k: usize) -> Matrix {
    let total = w.data.len();
    if k >= total {
        return w.clone();
    }
    let mut out = Matrix::zeros(w.rows, w.cols);
    if k == 0 {
        return out;
    }
    // threshold = k-th largest |value| via quickselect
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    let idx = total - k; // after ascending partition, elements [idx..] are top-k
    let (_, thresh, _) = mags.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
    let thresh = *thresh;
    // keep strictly-above first, then fill remaining budget with ties in
    // flat-index order (stable tie-break); total_cmp keeps the two passes
    // consistent with the select above when NaN magnitudes are present
    let mut kept = 0usize;
    for (i, &v) in w.data.iter().enumerate() {
        if v.abs().total_cmp(&thresh).is_gt() {
            out.data[i] = v;
            kept += 1;
        }
    }
    debug_assert!(kept <= k);
    if kept < k {
        for (i, &v) in w.data.iter().enumerate() {
            if kept == k {
                break;
            }
            if v.abs().total_cmp(&thresh).is_eq() && out.data[i] == 0.0 {
                // note: a genuine stored 0.0 with |0|==thresh only happens
                // when thresh==0, where keeping zeros is harmless
                out.data[i] = v;
                kept += 1;
            }
        }
    }
    out
}

/// Support mask (0/1) of the top-k projection.
pub fn topk_mask(w: &Matrix, k: usize) -> Matrix {
    topk_project(w, k).support_mask()
}

/// N:M projection: within every group of `m` consecutive weights along the
/// *input* dimension of each output column, keep the `n` largest magnitudes.
///
/// Panics when the pattern is malformed or `w.rows % m != 0`; callers that
/// handle untrusted shapes (the serve path, checkpoint loaders) should use
/// [`nm_project_checked`], which reports the same conditions as `Err`.
pub fn nm_project(w: &Matrix, n: usize, m: usize) -> Matrix {
    match nm_project_checked(w, n, m) {
        Ok(out) => out,
        Err(e) => panic!("{e}"),
    }
}

/// [`nm_project`] with the shape preconditions surfaced as `Result`
/// instead of panics: requires `0 < m`, `n <= m`, and `w.rows % m == 0`.
pub fn nm_project_checked(w: &Matrix, n: usize, m: usize) -> Result<Matrix> {
    ensure!(m > 0 && n <= m, "bad N:M {n}:{m}");
    ensure!(w.rows % m == 0, "n_in {} not divisible by M {}", w.rows, m);
    let mut out = Matrix::zeros(w.rows, w.cols);
    let mut order: Vec<usize> = Vec::with_capacity(m);
    for c in 0..w.cols {
        for g0 in (0..w.rows).step_by(m) {
            order.clear();
            order.extend(0..m);
            // stable sort by descending magnitude, lower index wins ties
            order.sort_by(|&a, &b| {
                let ma = w.at(g0 + a, c).abs();
                let mb = w.at(g0 + b, c).abs();
                mb.total_cmp(&ma).then(a.cmp(&b))
            });
            for &o in order.iter().take(n) {
                *out.at_mut(g0 + o, c) = w.at(g0 + o, c);
            }
        }
    }
    Ok(out)
}

/// Project according to a [`SparsityTarget`].
pub fn project(w: &Matrix, target: SparsityTarget) -> Matrix {
    match target {
        SparsityTarget::Unstructured(_) => {
            topk_project(w, target.keep_count(w.rows, w.cols))
        }
        SparsityTarget::NM { n, m } => nm_project(w, n, m),
    }
}

/// Project with per-entry scores instead of |value| (used by Wanda: the
/// kept entries are the top-scoring, but the *values* come from `w`).
/// `per_column`: selection group is each output column (Wanda's comparison
/// group); otherwise global.
pub fn project_by_score(
    w: &Matrix,
    scores: &Matrix,
    target: SparsityTarget,
    per_column: bool,
) -> Matrix {
    assert_eq!((w.rows, w.cols), (scores.rows, scores.cols));
    match target {
        SparsityTarget::NM { n, m } => {
            // N:M by score
            let mut out = Matrix::zeros(w.rows, w.cols);
            for c in 0..w.cols {
                for g0 in (0..w.rows).step_by(m) {
                    let mut order: Vec<usize> = (0..m).collect();
                    order.sort_by(|&a, &b| {
                        scores.at(g0 + b, c).total_cmp(&scores.at(g0 + a, c)).then(a.cmp(&b))
                    });
                    for &o in order.iter().take(n) {
                        *out.at_mut(g0 + o, c) = w.at(g0 + o, c);
                    }
                }
            }
            out
        }
        SparsityTarget::Unstructured(_) => {
            let mut out = Matrix::zeros(w.rows, w.cols);
            if per_column {
                let keep_per_col =
                    (target.keep_count(w.rows, w.cols) + w.cols - 1) / w.cols;
                let keep_per_col = keep_per_col.min(w.rows);
                for c in 0..w.cols {
                    let mut order: Vec<usize> = (0..w.rows).collect();
                    order.sort_by(|&a, &b| {
                        scores.at(b, c).total_cmp(&scores.at(a, c)).then(a.cmp(&b))
                    });
                    for &r in order.iter().take(keep_per_col) {
                        *out.at_mut(r, c) = w.at(r, c);
                    }
                }
            } else {
                let k = target.keep_count(w.rows, w.cols);
                let mut order: Vec<usize> = (0..w.data.len()).collect();
                order.sort_by(|&a, &b| {
                    scores.data[b].total_cmp(&scores.data[a]).then(a.cmp(&b))
                });
                for &i in order.iter().take(k) {
                    out.data[i] = w.data[i];
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn topk_exact_count() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(13, 7, &mut rng);
        for k in [0usize, 1, 10, 45, 91] {
            assert_eq!(topk_project(&w, k).nnz(), k);
        }
    }

    #[test]
    fn topk_keeps_largest() {
        let w = Matrix::from_vec(2, 2, vec![3.0, -1.0, 0.5, -2.0]);
        let p = topk_project(&w, 2);
        assert_eq!(p.data, vec![3.0, 0.0, 0.0, -2.0]);
    }

    #[test]
    fn topk_is_euclidean_projection_bruteforce() {
        // property: among all k-sparse matrices, projection minimizes
        // ||W - P||_F — verified by brute force over supports on 2x2
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let w = Matrix::randn(2, 2, &mut rng);
            let p = topk_project(&w, 2);
            let err_p = w.sub(&p).fro_norm_sq();
            for s0 in 0..4 {
                for s1 in (s0 + 1)..4 {
                    let mut cand = Matrix::zeros(2, 2);
                    cand.data[s0] = w.data[s0];
                    cand.data[s1] = w.data[s1];
                    assert!(w.sub(&cand).fro_norm_sq() >= err_p - 1e-6);
                }
            }
        }
    }

    #[test]
    fn topk_ties_stable() {
        let w = Matrix::from_vec(1, 4, vec![1.0, -1.0, 1.0, 1.0]);
        let p = topk_project(&w, 2);
        assert_eq!(p.data, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_k_geq_total_is_identity() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(3, 3, &mut rng);
        assert_eq!(topk_project(&w, 9), w);
        assert_eq!(topk_project(&w, 100), w);
    }

    #[test]
    fn nm_group_budget() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(16, 5, &mut rng);
        let p = nm_project(&w, 2, 4);
        for c in 0..5 {
            for g0 in (0..16).step_by(4) {
                let nnz = (g0..g0 + 4).filter(|&r| p.at(r, c) != 0.0).count();
                assert!(nnz <= 2);
            }
        }
        assert_eq!(p.nnz(), 16 * 5 / 2);
    }

    #[test]
    fn nm_keeps_largest_in_group() {
        let w = Matrix::from_vec(4, 1, vec![0.1, -5.0, 3.0, 0.2]);
        let p = nm_project(&w, 2, 4);
        assert_eq!(p.data, vec![0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn project_dispatches() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(8, 4, &mut rng);
        let u = project(&w, SparsityTarget::Unstructured(0.75));
        assert_eq!(u.nnz(), 8);
        let nm = project(&w, SparsityTarget::NM { n: 1, m: 4 });
        assert_eq!(nm.nnz(), 8);
    }

    #[test]
    fn project_by_score_values_from_w() {
        let w = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        // scores invert the magnitude ordering
        let s = Matrix::from_vec(2, 2, vec![4.0, 3.0, 2.0, 1.0]);
        let p = project_by_score(&w, &s, SparsityTarget::Unstructured(0.5), false);
        assert_eq!(p.data, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn project_by_score_per_column() {
        let w = Matrix::from_vec(4, 2, vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let s = w.clone();
        let p = project_by_score(&w, &s, SparsityTarget::Unstructured(0.5), true);
        // each column keeps its top 2
        assert_eq!(p.col(0), vec![0.0, 0.0, 3.0, 4.0]);
        assert_eq!(p.col(1), vec![0.0, 0.0, 30.0, 40.0]);
    }

    #[test]
    fn nm_checked_rejects_bad_shapes() {
        let w = Matrix::from_vec(6, 1, vec![1., 2., 3., 4., 5., 6.]);
        assert!(nm_project_checked(&w, 2, 4).is_err(), "6 rows not divisible by 4");
        assert!(nm_project_checked(&w, 3, 2).is_err(), "n > m");
        assert!(nm_project_checked(&w, 1, 0).is_err(), "m == 0");
        let ok = nm_project_checked(&w, 1, 2).unwrap();
        assert_eq!(ok, nm_project(&w, 1, 2));
    }

    #[test]
    fn nan_weights_do_not_panic_and_sort_first() {
        // total_cmp: |NaN| is the largest magnitude class, so a NaN weight
        // is deterministically *kept* rather than crashing the comparator.
        let w = Matrix::from_vec(4, 1, vec![1.0, f32::NAN, 3.0, 0.5]);
        let p = nm_project(&w, 2, 4);
        assert!(p.data[1].is_nan());
        assert_eq!(p.data[2], 3.0);
        assert_eq!(p.data[0], 0.0);
        assert_eq!(p.data[3], 0.0);

        // top-k select_nth path with a NaN present
        let t = topk_project(&w, 2);
        assert!(t.data[1].is_nan());
        assert_eq!(t.data[2], 3.0);
    }

    #[test]
    fn nan_scores_do_not_panic() {
        let w = Matrix::from_vec(4, 1, vec![1., 2., 3., 4.]);
        let s = Matrix::from_vec(4, 1, vec![f32::NAN, 1.0, 2.0, f32::NAN]);
        // positive NaN sorts above every finite score under total_cmp, so
        // both NaN-scored slots win the 2:4 budget — deterministically.
        let p = project_by_score(&w, &s, SparsityTarget::NM { n: 2, m: 4 }, true);
        assert_eq!(p.data, vec![1.0, 0.0, 0.0, 4.0]);
        let g = project_by_score(&w, &s, SparsityTarget::Unstructured(0.5), false);
        assert_eq!(g.data, vec![1.0, 0.0, 0.0, 4.0]);
        let c = project_by_score(&w, &s, SparsityTarget::Unstructured(0.5), true);
        assert_eq!(c.data, vec![1.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn project_by_score_nm() {
        let w = Matrix::from_vec(4, 1, vec![1., 2., 3., 4.]);
        let s = Matrix::from_vec(4, 1, vec![9., 1., 1., 8.]);
        let p = project_by_score(&w, &s, SparsityTarget::NM { n: 2, m: 4 }, true);
        assert_eq!(p.data, vec![1.0, 0.0, 0.0, 4.0]);
    }
}
