//! DSnoT (Zhang et al. 2023): training-free mask refinement — iteratively
//! grow/prune the support according to the change in reconstruction error.
//!
//! Faithful-in-spirit reimplementation (the original scores swaps with
//! per-feature activation statistics): starting from a Wanda mask, each
//! cycle considers, per output column, growing the zero weight with the
//! largest marginal error reduction r_ij^2 / H_ii (the optimal
//! one-coordinate update of the reconstruction objective) and pruning the
//! kept weight with the smallest removal cost w_ij^2 * H_ii. The swap is
//! applied when it strictly reduces the column objective, keeping the
//! non-zero budget constant — exactly the paper's grow/prune criterion
//! instantiated on the layer-wise objective (1).

use super::{wanda::Wanda, LayerProblem, PruneMethod};
use crate::config::{DsNoTConfig, SparsityTarget};
use crate::linalg::matmul::matmul;
use crate::linalg::Matrix;
use anyhow::Result;

/// Dynamic Sparse no Training. Hyperparameters come from [`DsNoTConfig`]
/// (see [`crate::pruning::MethodSpec`]).
#[derive(Default)]
pub struct DsNoT {
    pub cfg: DsNoTConfig,
}

impl DsNoT {
    pub fn with_config(cfg: DsNoTConfig) -> Self {
        DsNoT { cfg }
    }
}

impl PruneMethod for DsNoT {
    fn name(&self) -> &'static str {
        "dsnot"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        // initial mask from Wanda (as in the paper's default pipeline)
        let mut w = Wanda.prune(problem, target)?;
        let h = &problem.h;
        let n_in = problem.n_in();
        let n_out = problem.n_out();

        // residual R = G - H W, updated incrementally per swap
        let mut r = problem.g.sub(&matmul(h, &w));

        for j in 0..n_out {
            let nm_group = match target {
                SparsityTarget::NM { m, .. } => Some(m),
                _ => None,
            };
            for _cycle in 0..self.cfg.max_cycles {
                // grow candidate: zero entry with max r^2 / H_ii
                let mut best_grow: Option<(usize, f64)> = None;
                for i in 0..n_in {
                    if w.at(i, j) != 0.0 {
                        continue;
                    }
                    let hii = h.at(i, i).max(1e-12) as f64;
                    let rij = r.at(i, j) as f64;
                    let gain = rij * rij / hii;
                    if best_grow.map_or(true, |(_, g)| gain > g) {
                        best_grow = Some((i, gain));
                    }
                }
                // prune candidate: kept entry with min (w^2 H_ii + 2 w r)
                // = exact objective increase of zeroing coordinate i
                let mut best_prune: Option<(usize, f64)> = None;
                for i in 0..n_in {
                    let wij = w.at(i, j) as f64;
                    if wij == 0.0 {
                        continue;
                    }
                    let hii = h.at(i, i).max(1e-12) as f64;
                    let rij = r.at(i, j) as f64;
                    // removing w_ij changes objective by w^2 H_ii + 2 w r_ij
                    let cost = wij * wij * hii + 2.0 * wij * rij;
                    if best_prune.map_or(true, |(_, c)| cost < c) {
                        best_prune = Some((i, cost));
                    }
                }
                let (Some((gi, gain)), Some((pi, cost))) = (best_grow, best_prune) else {
                    break;
                };
                if gi == pi || gain - cost <= self.cfg.min_gain {
                    break;
                }
                // respect N:M: the grown weight must not overfill its group
                if let Some(m) = nm_group {
                    let g0 = (gi / m) * m;
                    let full = (g0..g0 + m)
                        .filter(|&rr| rr != pi && w.at(rr, j) != 0.0)
                        .count();
                    let budget = match target {
                        SparsityTarget::NM { n, .. } => n,
                        _ => unreachable!(),
                    };
                    if full >= budget {
                        break;
                    }
                }
                // apply: prune (pi, j), grow (gi, j) with its optimal value
                let old = w.at(pi, j);
                *w.at_mut(pi, j) = 0.0;
                for i in 0..n_in {
                    *r.at_mut(i, j) += h.at(i, pi) * old;
                }
                let delta = r.at(gi, j) / h.at(gi, gi).max(1e-12);
                *w.at_mut(gi, j) = delta;
                for i in 0..n_in {
                    *r.at_mut(i, j) -= h.at(i, gi) * delta;
                }
            }
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::check_target;
    use crate::pruning::testutil::random_problem;

    #[test]
    fn budget_preserved() {
        let p = random_problem(16, 8, 64, 0);
        let t = SparsityTarget::Unstructured(0.5);
        let w_wanda = Wanda.prune(&p, t).unwrap();
        let w = DsNoT::default().prune(&p, t).unwrap();
        assert_eq!(w.nnz(), w_wanda.nnz(), "grow/prune must keep nnz constant");
    }

    #[test]
    fn improves_on_wanda() {
        let p = random_problem(24, 12, 90, 1);
        let t = SparsityTarget::Unstructured(0.7);
        let w_wanda = Wanda.prune(&p, t).unwrap();
        let w = DsNoT::default().prune(&p, t).unwrap();
        assert!(
            p.rel_error(&w) <= p.rel_error(&w_wanda) + 1e-9,
            "dsnot {} !<= wanda {}",
            p.rel_error(&w),
            p.rel_error(&w_wanda)
        );
    }

    #[test]
    fn zero_cycles_is_wanda() {
        let p = random_problem(12, 6, 50, 2);
        let t = SparsityTarget::Unstructured(0.5);
        let d = DsNoT::with_config(DsNoTConfig { max_cycles: 0, ..Default::default() });
        assert_eq!(d.prune(&p, t).unwrap(), Wanda.prune(&p, t).unwrap());
    }

    #[test]
    fn respects_nm_after_swaps() {
        let p = random_problem(16, 4, 64, 3);
        let t = SparsityTarget::NM { n: 2, m: 4 };
        let w = DsNoT::default().prune(&p, t).unwrap();
        assert!(check_target(&w, t));
    }
}
