//! SparseGPT (Frantar & Alistarh 2023) reimplementation.
//!
//! OBS-style layer pruning: process input indices sequentially; within each
//! block choose the prune mask adaptively from the OBS saliency
//! w^2 / [H^-1]_ii, zero the pruned weights, and propagate the induced
//! error to the not-yet-processed weights via the inverse-Hessian row.
//! The inverse Hessian of the remaining (unprocessed) index set is
//! maintained with the exact OBS rank-1 downdate — mathematically the same
//! quantity SparseGPT reads off the Cholesky factor.

use super::{LayerProblem, PruneMethod};
use crate::config::{SparseGptConfig, SparsityTarget};
use crate::linalg::{Cholesky, Matrix};
use anyhow::Result;

/// SparseGPT with adaptive blockwise mask selection. Hyperparameters come
/// from [`SparseGptConfig`] (see [`crate::pruning::MethodSpec`]).
#[derive(Default)]
pub struct SparseGpt {
    pub cfg: SparseGptConfig,
}

impl SparseGpt {
    pub fn with_config(cfg: SparseGptConfig) -> Self {
        SparseGpt { cfg }
    }
}

impl PruneMethod for SparseGpt {
    fn name(&self) -> &'static str {
        "sparsegpt"
    }

    fn prune(&self, problem: &LayerProblem, target: SparsityTarget) -> Result<Matrix> {
        let n_in = problem.n_in();
        let n_out = problem.n_out();

        // damped H, then full inverse (downdated as indices are fixed)
        let mut h = problem.h.clone();
        let mean_diag: f32 = h.diag().iter().sum::<f32>() / n_in as f32;
        let damp = self.cfg.percdamp * mean_diag;
        for i in 0..n_in {
            *h.at_mut(i, i) += damp;
        }
        let mut hinv = Cholesky::new(&h)?.inverse();

        let mut w = problem.what.clone();
        let mut pruned = vec![false; n_in * n_out];

        let sparsity = target.sparsity_fraction();
        for b0 in (0..n_in).step_by(self.cfg.block_size) {
            let b1 = (b0 + self.cfg.block_size).min(n_in);
            self.select_block_mask(&w, &hinv, b0, b1, n_out, sparsity, target, &mut pruned);

            // sequential OBS elimination within the block
            for i in b0..b1 {
                let d = hinv.at(i, i).max(1e-10);
                // error vector across outputs for pruned (i, j)
                let mut err = vec![0.0f32; n_out];
                for j in 0..n_out {
                    if pruned[i * n_out + j] {
                        err[j] = w.at(i, j) / d;
                        *w.at_mut(i, j) = 0.0;
                    }
                }
                // propagate: W[r, j] -= err[j] * Hinv[r, i] for r > i
                for r in (i + 1)..n_in {
                    let hri = hinv.at(r, i);
                    if hri == 0.0 {
                        continue;
                    }
                    let row = w.row_mut(r);
                    for j in 0..n_out {
                        row[j] -= err[j] * hri;
                    }
                }
                // OBS downdate: remove index i from the active inverse
                downdate(&mut hinv, i);
            }
        }
        Ok(w)
    }
}

impl SparseGpt {
    /// Choose, per output column, which block entries to prune so each
    /// column hits the target sparsity within this block (or the N:M
    /// pattern), ranked by OBS saliency w^2 / [H^-1]_ii.
    #[allow(clippy::too_many_arguments)]
    fn select_block_mask(
        &self,
        w: &Matrix,
        hinv: &Matrix,
        b0: usize,
        b1: usize,
        n_out: usize,
        sparsity: f64,
        target: SparsityTarget,
        pruned: &mut [bool],
    ) {
        let blen = b1 - b0;
        let saliency = |i: usize, j: usize| {
            let d = hinv.at(i, i).max(1e-10);
            let wij = w.at(i, j);
            wij * wij / (d * d)
        };
        match target {
            SparsityTarget::Unstructured(_) => {
                let n_prune = ((sparsity * blen as f64).round() as usize).min(blen);
                for j in 0..n_out {
                    let mut order: Vec<usize> = (b0..b1).collect();
                    order.sort_by(|&a, &b| {
                        saliency(a, j).partial_cmp(&saliency(b, j)).unwrap()
                    });
                    for &i in order.iter().take(n_prune) {
                        pruned[i * n_out + j] = true;
                    }
                }
            }
            SparsityTarget::NM { n, m } => {
                for j in 0..n_out {
                    for g0 in (b0..b1).step_by(m) {
                        let g1 = (g0 + m).min(b1);
                        let mut order: Vec<usize> = (g0..g1).collect();
                        order.sort_by(|&a, &b| {
                            saliency(a, j).partial_cmp(&saliency(b, j)).unwrap()
                        });
                        let n_prune = (g1 - g0).saturating_sub(n);
                        for &i in order.iter().take(n_prune) {
                            pruned[i * n_out + j] = true;
                        }
                    }
                }
            }
        }
    }
}

/// OBS downdate: after fixing index i, the inverse Hessian of the remaining
/// set is Hinv' = Hinv - Hinv[:,i] Hinv[i,:] / Hinv[i,i]. Row/col i become
/// irrelevant afterwards (indices <= i are never touched again).
fn downdate(hinv: &mut Matrix, i: usize) {
    let n = hinv.rows;
    let d = hinv.at(i, i);
    if d.abs() < 1e-12 {
        return;
    }
    let col: Vec<f32> = (0..n).map(|r| hinv.at(r, i)).collect();
    for r in (i + 1)..n {
        let cr = col[r] / d;
        if cr == 0.0 {
            continue;
        }
        let row = hinv.row_mut(r);
        for c in (i + 1)..n {
            row[c] -= cr * col[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::gram;
    use crate::pruning::magnitude::MagnitudePruning;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::{check_target, LayerProblem};
    use crate::util::Rng;

    #[test]
    fn respects_budget_unstructured() {
        let p = random_problem(32, 8, 100, 0);
        let t = SparsityTarget::Unstructured(0.5);
        let w = SparseGpt::default().prune(&p, t).unwrap();
        // per-block-per-column rounding can wiggle slightly; allow 2%
        let max_nnz = (t.keep_count(32, 8) as f64 * 1.02) as usize;
        assert!(w.nnz() <= max_nnz, "nnz={} max={}", w.nnz(), max_nnz);
    }

    #[test]
    fn respects_nm_pattern() {
        let p = random_problem(16, 4, 64, 1);
        let t = SparsityTarget::NM { n: 2, m: 4 };
        let w = SparseGpt::with_config(SparseGptConfig { block_size: 16, ..Default::default() })
            .prune(&p, t)
            .unwrap();
        assert!(check_target(&w, t));
    }

    #[test]
    fn beats_magnitude_pruning() {
        // the whole point of OBS updates: lower reconstruction error than MP
        let p = random_problem(32, 16, 120, 2);
        let t = SparsityTarget::Unstructured(0.6);
        let w_sg = SparseGpt::default().prune(&p, t).unwrap();
        let w_mp = MagnitudePruning.prune(&p, t).unwrap();
        let (e_sg, e_mp) = (p.rel_error(&w_sg), p.rel_error(&w_mp));
        assert!(e_sg < e_mp, "sparsegpt {e_sg} !< mp {e_mp}");
    }

    #[test]
    fn single_column_is_exact_obs() {
        // with one output and one block, pruning one weight must match the
        // analytic OBS compensation for the surviving weights
        let mut rng = Rng::new(3);
        let n = 4;
        let x = Matrix::randn(30, n, &mut rng);
        let h = gram(&x);
        let what = Matrix::from_vec(n, 1, vec![1.0, 0.05, -0.8, 0.6]);
        let p = LayerProblem::from_gram(h, what).unwrap();
        let sg = SparseGpt::with_config(SparseGptConfig { block_size: n, percdamp: 0.0 });
        let w = sg.prune(&p, SparsityTarget::Unstructured(0.25)).unwrap();
        assert_eq!(w.nnz(), 3);
        // surviving weights must give lower error than naive zeroing
        let naive = {
            let mut v = p.what.clone();
            // zero the same entry sparsegpt chose
            for i in 0..n {
                if w.at(i, 0) == 0.0 {
                    *v.at_mut(i, 0) = 0.0;
                }
            }
            v
        };
        assert!(p.rel_error(&w) <= p.rel_error(&naive) + 1e-9);
    }

    #[test]
    fn downdate_matches_submatrix_inverse() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(20, 5, &mut rng);
        let mut h = gram(&x);
        for i in 0..5 {
            *h.at_mut(i, i) += 0.1;
        }
        let mut hinv = Cholesky::new(&h).unwrap().inverse();
        downdate(&mut hinv, 0);
        // compare [1.., 1..] block against the inverse of H[1.., 1..]
        let mut hsub = Matrix::zeros(4, 4);
        for r in 0..4 {
            for c in 0..4 {
                *hsub.at_mut(r, c) = h.at(r + 1, c + 1);
            }
        }
        let hsub_inv = Cholesky::new(&hsub).unwrap().inverse();
        for r in 0..4 {
            for c in 0..4 {
                assert!(
                    (hinv.at(r + 1, c + 1) - hsub_inv.at(r, c)).abs() < 1e-3,
                    "({r},{c})"
                );
            }
        }
    }
}
