//! Shared bench harness (criterion is unavailable offline): warmup +
//! repeated timing with summary stats, plus the standard experiment
//! fixtures used by `rust/benches/*`.

use crate::config::SparsityTarget;
use crate::coordinator::scheduler::single_layer_problem;
use crate::data::{sample_windows, Corpus};
use crate::linalg::Matrix;
use crate::model::Model;
use crate::pruning::LayerProblem;
use crate::util::{Rng, Stats, Timer};
use anyhow::Result;
use std::path::Path;

/// Time `f` `reps` times after `warmup` runs; returns per-run seconds.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut stats = Stats::new();
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(f());
        stats.push(t.elapsed_secs());
    }
    stats
}

/// Synthetic anisotropic layer problem (used when artifacts are absent).
/// X is attached (moved, no copy) so the sharded benches can exercise
/// activation shipping on these problems.
pub fn synthetic_problem(n_in: usize, n_out: usize, rows: usize, seed: u64) -> LayerProblem {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::randn(rows, n_in, &mut rng);
    for c in 0..n_in {
        let s = 0.2 + 2.0 * ((c * 37 % n_in) as f32 / n_in as f32);
        for r in 0..rows {
            *x.at_mut(r, c) *= s;
        }
    }
    let what = Matrix::randn(n_in, n_out, &mut rng);
    let mut p = LayerProblem::from_activations(&x, &what).unwrap();
    p.attach_activations(std::sync::Arc::new(x)).unwrap();
    p
}

/// Are the build artifacts present?
pub fn artifacts_ready() -> bool {
    Path::new("artifacts/manifest.json").exists()
        && Path::new("artifacts/corpus.bin").exists()
        && Path::new("artifacts/model_alps-tiny.bin").exists()
}

/// The paper's single-layer fixture (Fig. 2 / Table 1: one real trained
/// layer + real calibration activations). Falls back to synthetic if
/// artifacts are missing.
pub fn paper_layer_problem() -> Result<LayerProblem> {
    if artifacts_ready() {
        let dir = Path::new("artifacts");
        let model = Model::load(dir, "alps-small")?;
        let corpus = Corpus::load(&dir.join("corpus.bin"))?;
        let calib = sample_windows(corpus.split("train")?, 16, model.cfg.seq_len, 0xCA11B);
        // mlp.w2 of block 0: the (d_ff x d_model) = 768x192 analogue of the
        // paper's self_attn.k_proj 5120x5120 experiment
        single_layer_problem(&model, &calib, 0, "mlp.w2")
    } else {
        eprintln!("NOTE: artifacts missing, using synthetic layer");
        Ok(synthetic_problem(256, 128, 1024, 0))
    }
}

/// The Table-1-right fixture: the *largest* trained layer (alps-base
/// mlp.w2, 1024x256) where the per-column backsolve cost is dominated by
/// the O(|S|^3) factorizations — the regime of the paper's 5120x5120
/// experiment. Synthetic fallback keeps the same shape.
pub fn large_layer_problem() -> Result<LayerProblem> {
    if artifacts_ready() {
        let dir = Path::new("artifacts");
        let model = Model::load(dir, "alps-base")?;
        let corpus = Corpus::load(&dir.join("corpus.bin"))?;
        let calib = sample_windows(corpus.split("train")?, 16, model.cfg.seq_len, 0xCA11B);
        single_layer_problem(&model, &calib, 0, "mlp.w2")
    } else {
        eprintln!("NOTE: artifacts missing, using synthetic layer");
        Ok(synthetic_problem(1024, 256, 2048, 0))
    }
}

/// Standard sparsity grid of the paper's evaluation.
pub fn sparsity_grid() -> Vec<SparsityTarget> {
    [0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
        .iter()
        .map(|&s| SparsityTarget::Unstructured(s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let s = bench(1, 5, || (0..1000).sum::<usize>());
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn synthetic_problem_shapes() {
        let p = synthetic_problem(16, 8, 64, 0);
        assert_eq!((p.n_in(), p.n_out()), (16, 8));
    }

    #[test]
    fn grid_has_six_points() {
        assert_eq!(sparsity_grid().len(), 6);
    }
}
