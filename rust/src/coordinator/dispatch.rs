//! Coordinator-side dispatcher for distributed pruning: a
//! [`ShardedEngine`] implementing [`crate::pruning::Engine`] that ships
//! [`LayerProblem`]s to an **elastic pool** of `alps worker` processes
//! over the binary frame protocol ([`crate::pruning::wire`], version 3)
//! and reassembles results deterministically.
//!
//! Design:
//!
//! * **Owned jobs, long-lived pool**: each layer solve is an `Arc`'d
//!   self-contained [`OwnedJob`] — target, `Arc<LayerProblem>`, and a
//!   positional result slot in its block's [`BlockState`] — pushed onto
//!   one shared queue that outlives any single block solve. Dispatcher
//!   threads are spawned once per run (detached `std::thread::spawn`,
//!   joined at [`ShardedEngine::close`]), not scoped per block: nothing
//!   in the dispatch path borrows from a block's stack frame, which is
//!   what lets workers join and leave while a run is in flight.
//! * **One dispatcher thread per pool member**, all draining the shared
//!   queue — a fast worker naturally takes more layers (work stealing by
//!   construction), and layer order never matters because results land in
//!   a slot indexed by job position. Heartbeat- and result-derived
//!   per-worker solve-time EWMAs feed a smarter dequeue: the **slowest**
//!   member skips the queue head and takes the **smallest** pending layer
//!   (cost ∝ `n_in · n_out`), so a straggler never strands a huge layer
//!   at the end of a block. Any dequeue policy is bit-safe — reassembly
//!   is positional.
//! * **Dynamic membership**: [`ShardedEngine::listen_for_registrations`]
//!   accepts [`tag::REGISTER`] frames (new in frame version 3) carrying a
//!   worker's advertised `host:port`; the coordinator adds the member,
//!   spawns a dispatcher for it, and acks by echoing the frame —
//!   `alps worker --register host:port` dials it and joins mid-run.
//!   Departures (exhausted reconnect attempts, heartbeat silence, BUSY
//!   past patience) requeue the member's owned jobs at the front of the
//!   queue and retire the member for good; joins and leaves feed the
//!   fleet gauges and, when a [`StatusBoard`] is attached, the
//!   `--status-addr` fleet-size series and membership event log.
//! * **Persistent connections**: an idle dispatcher parks its TCP
//!   connection in its member slot and picks it up again when work
//!   arrives, so an N-block run dials each worker once, not N times. A
//!   parked connection that went stale between blocks (worker restarted,
//!   NAT timeout) gets one free redial — staleness is not a worker
//!   failure and never burns a retry attempt.
//! * **Heartbeat liveness**: workers emit a [`tag::HEARTBEAT`] frame
//!   every couple of seconds while solving, so *any* silence longer than
//!   [`ShardedConfig::heartbeat_grace`] (default 30 s) means the worker
//!   is gone — not merely slow — and its in-flight jobs reroute
//!   immediately instead of waiting out the
//!   [`ShardedConfig::idle_timeout`] (default 600 s, kept as the
//!   wall-clock ceiling on any single frame transfer, which also defeats
//!   byte-dribbling peers). Beats renew the silence clock (only a
//!   delivered result renews the reconnect-attempt budget, so a
//!   beat-then-crash worker still exhausts its attempts).
//! * **Per-worker outstanding-request limit**
//!   ([`ShardedConfig::max_outstanding`]): each connection pipelines a
//!   bounded number of in-flight solves, enough to hide the round trip
//!   without buffering a whole block on one worker.
//! * **Activation shipping** ([`ShardedConfig::ship_activations`]): when
//!   the layer problem retains its calibration rows X `[n, n_in]` and X
//!   is strictly smaller than the gram (`n < n_in`), the request ships X
//!   instead of H `[n_in, n_in]` and the worker builds H itself with the
//!   same deterministic kernel — O(n·n_in) wire bytes instead of
//!   O(n_in^2), and never an inflation for narrow layers (the cheaper
//!   encoding is chosen per layer).
//! * **Retry on disconnect**: a failed connect, a broken connection, or a
//!   hung worker requeues that member's in-flight jobs at the *front* of
//!   the queue (another member picks them up next) and the member gets a
//!   bounded number of reconnect attempts
//!   ([`ShardedConfig::max_attempts`]). The run completes as long as one
//!   member survives; only when the live fleet is empty do unsolved
//!   layers fail the block.
//! * **Solver errors are not retried**: a worker answering `tag::ERROR`
//!   for a job this connection owns hit a deterministic failure (bad
//!   target for the method, degenerate problem) that would fail
//!   identically anywhere, so that job's whole block aborts with the
//!   message. The member survives — a solver error is not a transport
//!   fault. Transport-level refusals (`tag::BUSY` at the connection cap,
//!   or an ERROR carrying the worker's protocol sentinel instead of an
//!   owned job id) stay retryable.
//! * **Observability**: the dispatcher feeds the process-global
//!   [`crate::obs`] registry — per-worker RPC latency histograms
//!   (`alps_coord_rpc_seconds{worker=...}`), burned reconnect attempts
//!   (`alps_coord_retries_total`), rerouted in-flight jobs
//!   (`alps_coord_reroutes_total`), request payload bytes split by
//!   calibration encoding (`alps_coord_wire_tx_bytes_total{calib=...}`),
//!   and the fleet lifecycle (`alps_coord_fleet_size`,
//!   `alps_coord_joins_total`, `alps_coord_leaves_total`). All recording
//!   is lock-free and off the result path: instrumentation cannot change
//!   a bit of the reassembled weights.
//! * **Bit-identical results**: matrices travel bit-exactly
//!   (`to_le_bytes` round-trip), the worker rebuilds the problem with the
//!   same deterministic kernels (including the gram, when activations are
//!   shipped), and reassembly is positional — a sharded run, *including
//!   one with workers joining and leaving mid-flight*, equals a
//!   [`NativeEngine`] run to the last bit (proven by
//!   `tests/integration_sharded.rs` and the CI smoke step).

use crate::config::SparsityTarget;
use crate::net::framing::{read_frame_deadline, write_frame, FrameRead};
use crate::net::lock;
use crate::obs::{Counter, Gauge};
use crate::pruning::engine::{Engine, LayerJob, LayerResult};
use crate::pruning::status::StatusBoard;
use crate::pruning::wire::{self, tag, CalibRef};
use crate::pruning::{LayerProblem, MethodSpec};
use anyhow::{bail, Context as _, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Dispatcher tuning knobs.
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    /// Pipelined in-flight solves per worker connection.
    pub max_outstanding: usize,
    /// Connect/reconnect attempts per worker before it is written off.
    pub max_attempts: usize,
    /// Largest accepted response frame.
    pub max_frame_bytes: usize,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// Legacy silence ceiling (`--shard-idle`). The read loop waits
    /// `heartbeat_grace.min(idle_timeout)` for the next byte, so with
    /// heartbeats the grace is the effective budget and this only still
    /// bites when configured *below* the grace; it survives so operators
    /// who tuned `--shard-idle` down keep their tighter bound.
    pub idle_timeout: Duration,
    /// A worker owing us results that sends *nothing* — no result, no
    /// heartbeat — for this long is dead; its in-flight jobs reroute
    /// immediately. Must comfortably exceed the pool's worker-side beat
    /// interval (`alps worker --heartbeat-secs`, default 2 s — the CLI
    /// enforces grace >= 15 s and beat <= 5 s so no legal pair can
    /// cross); a grace below the beat interval declares every healthy
    /// worker dead mid-solve.
    pub heartbeat_grace: Duration,
    /// Pause between reconnect attempts.
    pub retry_backoff: Duration,
    /// How long to keep retrying a worker that answers BUSY (at its
    /// connection cap) before writing it off. Separate from
    /// `max_attempts`: a saturated worker is healthy and a slot may free
    /// at any moment, so it gets far more patience than a broken one.
    pub busy_patience: Duration,
    /// Ship calibration activations X instead of the gram H whenever the
    /// layer problem retains them *and* X is strictly smaller
    /// (`rows < n_in`) — O(n·n_in) wire bytes instead of O(n_in^2) for
    /// wide layers, with the gram kept for layers where it wins; the
    /// worker rebuilds the identical H either way.
    pub ship_activations: bool,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            max_outstanding: 2,
            max_attempts: 3,
            max_frame_bytes: 1 << 30,
            connect_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(600),
            heartbeat_grace: Duration::from_secs(30),
            retry_backoff: Duration::from_millis(100),
            busy_patience: Duration::from_secs(60),
            ship_activations: false,
        }
    }
}

/// Poll interval for every wait-for-state loop in the pool: an idle
/// dispatcher waiting for work, the block-completion wait in
/// [`ShardedEngine::dispatch`], and the registration accept loop.
const WAIT_POLL: Duration = Duration::from_millis(50);

/// EWMA smoothing for per-member solve seconds: `new = (1-α)·old + α·x`.
const EWMA_ALPHA: f64 = 0.3;

/// Process-global coordinator instrumentation. Retries are burned
/// reconnect attempts, reroutes are in-flight jobs requeued off a failed
/// member, the tx counters split solve-request payload bytes by
/// calibration encoding, and the fleet gauge/counters track dynamic
/// membership (seed members count as joins too, so
/// `joins - leaves = fleet_size` at any instant).
struct CoordMetrics {
    retries: Counter,
    reroutes: Counter,
    tx_gram: Counter,
    tx_acts: Counter,
    joins: Counter,
    leaves: Counter,
    fleet: Gauge,
}

fn coord_metrics() -> &'static CoordMetrics {
    static M: std::sync::OnceLock<CoordMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| {
        let r = crate::obs::global();
        let tx = "alps_coord_wire_tx_bytes_total";
        let tx_help = "solve-request payload bytes sent, by calibration encoding";
        CoordMetrics {
            retries: r.counter("alps_coord_retries_total", "worker reconnect attempts burned", &[]),
            reroutes: r
                .counter("alps_coord_reroutes_total", "in-flight jobs requeued off a worker", &[]),
            tx_gram: r.counter(tx, tx_help, &[("calib", "gram")]),
            tx_acts: r.counter(tx, tx_help, &[("calib", "activations")]),
            joins: r.counter(
                "alps_coord_joins_total",
                "workers that joined the fleet (seed list + REGISTER frames)",
                &[],
            ),
            leaves: r.counter(
                "alps_coord_leaves_total",
                "workers written off the fleet for good",
                &[],
            ),
            fleet: r.gauge(
                "alps_coord_fleet_size",
                "live dispatcher-backed workers in the fleet",
                &[],
            ),
        }
    })
}

/// Result collection for one `solve_block` call. Jobs hold an `Arc` to
/// their block, so a block whose dispatch already failed (or returned)
/// stays alive until the last straggler result lands harmlessly in it.
struct BlockState {
    /// One slot per job, positional — deterministic reassembly.
    results: Mutex<Vec<Option<LayerResult>>>,
    /// Slots not yet filled; the block is done when this hits zero.
    unsolved: AtomicUsize,
    /// First deterministic solver error; aborts the block.
    fatal: Mutex<Option<String>>,
}

/// One self-contained layer solve: everything a dispatcher needs to ship
/// the job and land the result, with no borrows into any stack frame.
struct OwnedJob {
    /// Position in the block — the result slot index and the wire job id.
    slot: usize,
    target: SparsityTarget,
    problem: Arc<LayerProblem>,
    block: Arc<BlockState>,
}

impl OwnedJob {
    /// Relative solve-cost proxy (`n_in · n_out`) for the
    /// smallest-layer-to-slowest-member dequeue policy.
    fn cost(&self) -> u64 {
        (self.problem.h.rows as u64).saturating_mul(self.problem.what.cols.max(1) as u64)
    }
}

/// One pool member: a worker address, its parked connection, and its
/// liveness + solve-time estimate. `alive == false` is permanent — a
/// written-off member never rejoins except through a fresh REGISTER.
struct Member {
    addr: String,
    /// Connection parked here while the member's dispatcher idles (and
    /// across block solves); taking it is a `from_cache` reuse that earns
    /// a free redial on staleness.
    conn: Mutex<Option<TcpStream>>,
    alive: AtomicBool,
    /// EWMA of delivered solve seconds as `f64` bits; 0 = no data yet.
    /// Raised toward a heartbeat's elapsed time when an in-progress solve
    /// already exceeds the average — a straggler announces itself before
    /// its result lands.
    ewma_bits: AtomicU64,
}

impl Member {
    fn new(addr: String) -> Member {
        Member {
            addr,
            conn: Mutex::new(None),
            alive: AtomicBool::new(true),
            ewma_bits: AtomicU64::new(0),
        }
    }

    fn ewma(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }

    /// Fold a delivered solve's seconds into the estimate.
    fn fold_ewma(&self, secs: f64) {
        if !secs.is_finite() || secs <= 0.0 {
            return;
        }
        let old = self.ewma();
        let new = if old > 0.0 { (1.0 - EWMA_ALPHA) * old + EWMA_ALPHA * secs } else { secs };
        self.ewma_bits.store(new.to_bits(), Ordering::Relaxed);
    }

    /// A heartbeat proves the current solve has already run `secs`; an
    /// estimate below that is stale — raise it (never lower it here).
    fn raise_ewma_floor(&self, secs: f64) {
        if secs.is_finite() && secs > self.ewma() {
            self.ewma_bits.store(secs.to_bits(), Ordering::Relaxed);
        }
    }
}

/// The long-lived dispatch pool: the shared job queue, the member fleet,
/// and the dispatcher threads. Owned via `Arc` by the engine, every
/// dispatcher thread, and the registration listener.
struct Pool {
    spec: MethodSpec,
    cfg: ShardedConfig,
    /// Jobs not yet assigned (rerouted jobs return to the front).
    pending: Mutex<VecDeque<Arc<OwnedJob>>>,
    members: Mutex<Vec<Arc<Member>>>,
    /// Dispatcher thread handles, joined at [`ShardedEngine::close`].
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Raised by `close` to stop every dispatcher and the registration
    /// listener; reset afterwards so a later solve can reseed the fleet.
    shutdown: AtomicBool,
    /// Set once the seed worker list has been turned into members.
    seeded: AtomicBool,
    /// Live-progress sink: heartbeats and membership events go here.
    board: Mutex<Option<Arc<StatusBoard>>>,
    /// Transport-level failure per written-off member, drained by the
    /// next `dispatch` for its error / degraded-pool diagnostics.
    worker_errors: Mutex<Vec<String>>,
}

impl Pool {
    fn new(spec: MethodSpec, cfg: ShardedConfig) -> Pool {
        Pool {
            spec,
            cfg,
            pending: Mutex::new(VecDeque::new()),
            members: Mutex::new(Vec::new()),
            threads: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            seeded: AtomicBool::new(false),
            board: Mutex::new(None),
            worker_errors: Mutex::new(Vec::new()),
        }
    }

    fn live_members(&self) -> usize {
        lock(&self.members).iter().filter(|m| m.alive.load(Ordering::SeqCst)).count()
    }

    fn board(&self) -> Option<Arc<StatusBoard>> {
        lock(&self.board).clone()
    }

    /// Add a member and spawn its dispatcher. Re-registering a live
    /// address is idempotent (`false`); registering the address of a
    /// written-off member replaces the dead entry with a fresh one.
    fn add_member(self: &Arc<Self>, addr: &str) -> bool {
        let member = Arc::new(Member::new(addr.to_string()));
        {
            let mut members = lock(&self.members);
            if members.iter().any(|m| m.addr == addr && m.alive.load(Ordering::SeqCst)) {
                return false;
            }
            members.retain(|m| m.addr != addr || m.alive.load(Ordering::SeqCst));
            members.push(member.clone());
        }
        let met = coord_metrics();
        met.joins.inc();
        met.fleet.set(self.live_members() as f64);
        if let Some(board) = self.board() {
            board.note_worker_joined(addr);
        }
        let pool = self.clone();
        let handle = std::thread::spawn(move || pool.member_loop(&member));
        lock(&self.threads).push(handle);
        true
    }

    /// Retire a member for good: record why, update the fleet metrics,
    /// and clear its live status (the `solving` entry AND its stale
    /// ADMM-iteration gauge series — a departed worker must not keep
    /// publishing a frozen iteration count).
    fn leave(&self, member: &Member, error: String) {
        member.alive.store(false, Ordering::SeqCst);
        lock(&self.worker_errors).push(error);
        let met = coord_metrics();
        met.leaves.inc();
        met.fleet.set(self.live_members() as f64);
        if let Some(board) = self.board() {
            board.note_worker_left(&member.addr);
        }
    }

    /// Shared failure epilogue for every retryable connection-level fault
    /// in [`Pool::member_loop`]: a stale parked connection redials for
    /// free; otherwise one reconnect attempt is consumed (with the
    /// configured backoff before the retry) and the member leaves the
    /// fleet — `true` — once the budget is gone. Keeping the policy in
    /// one place keeps the six failure sites from drifting.
    fn written_off(
        &self,
        member: &Member,
        attempts: &mut usize,
        from_cache: bool,
        error: impl FnOnce() -> String,
    ) -> bool {
        if from_cache {
            // stale parked connection (worker restarted or link timed out
            // between blocks): one free redial, no attempt burned
            return false;
        }
        *attempts += 1;
        coord_metrics().retries.inc();
        if *attempts >= self.cfg.max_attempts {
            self.leave(member, error());
            return true;
        }
        std::thread::sleep(self.cfg.retry_backoff);
        false
    }

    /// True when `member` has the worst solve-time estimate in the live
    /// fleet — and at least one *other* live member has data, so the
    /// policy never fires on a fleet with nothing to compare against.
    fn is_slowest(&self, member: &Member) -> bool {
        let mine = member.ewma();
        if mine <= 0.0 {
            return false;
        }
        let members = lock(&self.members);
        let mut best_other = 0.0f64;
        for m in members.iter() {
            if std::ptr::eq(m.as_ref(), member) || !m.alive.load(Ordering::SeqCst) {
                continue;
            }
            let e = m.ewma();
            if e > best_other {
                best_other = e;
            }
        }
        best_other > 0.0 && mine > best_other
    }

    /// Dequeue the next job for `member`. Default is queue order; when
    /// the member is provably the slowest in the fleet and there is a
    /// choice, it takes the smallest pending layer instead, so a
    /// straggler never strands a huge layer at the end of a block. Jobs
    /// whose block already failed are dropped on sight.
    fn take_job(&self, member: &Member) -> Option<Arc<OwnedJob>> {
        loop {
            let job = {
                let mut pending = lock(&self.pending);
                if pending.len() > 1 && self.is_slowest(member) {
                    let smallest = pending
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, j)| j.cost())
                        .map(|(i, _)| i);
                    match smallest {
                        Some(i) => pending.remove(i),
                        None => pending.pop_front(),
                    }
                } else {
                    pending.pop_front()
                }
            }?;
            if lock(&job.block.fatal).is_none() {
                return Some(job);
            }
        }
    }

    /// Land a delivered result in its block's slot (first delivery wins;
    /// a straggler from a rerouted duplicate is dropped) and fold the
    /// solve time into the member's estimate.
    fn deliver(&self, member: &Member, job: &OwnedJob, resp: wire::SolveResponse) {
        member.fold_ewma(resp.secs);
        let mut results = lock(&job.block.results);
        if job.slot < results.len() && results[job.slot].is_none() {
            results[job.slot] = Some(LayerResult {
                w: resp.w,
                secs: resp.secs,
                admm_iters: resp.admm_iters as usize,
                worker: Some(member.addr.clone()),
            });
            drop(results);
            job.block.unsolved.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Return a member's in-flight jobs to the *front* of the queue so a
    /// surviving member reroutes them before taking fresh work.
    fn requeue(&self, member: &Member, in_flight: &mut VecDeque<Arc<OwnedJob>>) {
        if in_flight.is_empty() {
            return;
        }
        coord_metrics().reroutes.add(in_flight.len() as u64);
        if let Some(board) = self.board() {
            // whatever this worker was live-reporting is now abandoned:
            // clear its "solving" status entry so a dead worker doesn't
            // show as forever in-progress
            board.note_worker_stalled(&member.addr);
        }
        let mut pending = lock(&self.pending);
        while let Some(job) = in_flight.pop_back() {
            pending.push_front(job);
        }
    }

    /// One member's dispatch loop, alive for the whole run: idle (with
    /// the connection parked) while the queue is empty, otherwise connect
    /// (or unpark), keep up to `max_outstanding` solves in flight, and
    /// reroute on failure. Returns only at shutdown or when the member is
    /// written off the fleet.
    fn member_loop(&self, member: &Arc<Member>) {
        let addr = member.addr.as_str();
        // registered once per worker address; lock-free to observe
        let rpc_secs = crate::obs::global().histogram(
            "alps_coord_rpc_seconds",
            "send-to-result latency of a remote layer solve",
            &[("worker", addr)],
            &crate::obs::LATENCY_EDGES,
        );
        let mut attempts = 0usize;
        // set at the first BUSY answer; cleared by any successful solve
        let mut busy_since: Option<Instant> = None;
        'idle: loop {
            if self.shutdown.load(Ordering::SeqCst) || !member.alive.load(Ordering::SeqCst) {
                return;
            }
            if lock(&self.pending).is_empty() {
                // nothing to do anywhere; jobs in flight on other members
                // may still reroute here, so stay ready
                std::thread::sleep(WAIT_POLL);
                continue 'idle;
            }
            // a connection parked while idling (or by a previous block) is
            // reused; if it went stale in between, its failure below
            // redials for free (`from_cache`) instead of burning an attempt
            let (stream, mut from_cache) = match lock(&member.conn).take() {
                Some(s) => (s, true),
                None => match connect(addr, self.cfg.connect_timeout) {
                    Ok(s) => (s, false),
                    Err(e) => {
                        if self.written_off(member, &mut attempts, false, || {
                            format!("{addr}: {e}")
                        }) {
                            return;
                        }
                        continue 'idle;
                    }
                },
            };
            let mut reader = match stream.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    self.leave(member, format!("{addr}: clone failed: {e}"));
                    return;
                }
            };
            let mut writer = stream;
            // in-flight jobs, in send order (the worker answers one
            // connection's requests sequentially, so the front-most job
            // with a matching id is always the right one — job ids are
            // block-local slots and may repeat across blocks)
            let mut in_flight: VecDeque<Arc<OwnedJob>> = VecDeque::new();
            // send instants for the RPC-latency histogram, keyed by slot
            // (tiny: bounded by max_outstanding). Dropped wholesale with
            // the connection on reroute — a rerouted job's latency would
            // measure the failure, not the solve.
            let mut sent_at: Vec<(usize, Instant)> = Vec::new();
            // last moment this worker proved it is working *for us*: a
            // successful send, an owned RESULT/ERROR, or an owned
            // HEARTBEAT. Frames for jobs we don't own (a desynced or
            // hostile peer echoing someone else's beats) deliberately do
            // NOT renew it — otherwise such a peer could pin our in-flight
            // jobs forever without ever tripping the grace.
            let mut last_owned_signal = Instant::now();
            // cleared when a pipelined send stalls: a busy worker only
            // reads between solves, so a huge second frame can exceed the
            // socket buffer and the write timeout without anything being
            // wrong — stop sending, keep reading (the write may have been
            // partial, so the channel can't carry further requests), and
            // replace the connection once the in-flight drain completes
            let mut can_send = true;
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    self.requeue(member, &mut in_flight);
                    return;
                }
                // top up the pipeline
                while can_send && in_flight.len() < self.cfg.max_outstanding {
                    let Some(job) = self.take_job(member) else { break };
                    let problem = job.problem.as_ref();
                    // ship raw activations instead of the gram when
                    // configured, retained, and *strictly smaller* — for
                    // rows >= n_in the gram is the cheaper payload, so the
                    // flag picks the winning encoding per layer instead of
                    // inflating narrow layers
                    let calib = match (self.cfg.ship_activations, &problem.x) {
                        (true, Some(x)) if x.rows < problem.h.rows => {
                            CalibRef::Activations(x.as_ref())
                        }
                        _ => CalibRef::Gram(&problem.h),
                    };
                    let shipped_x = matches!(calib, CalibRef::Activations(_));
                    let payload = wire::encode_solve(
                        job.slot as u64,
                        job.target,
                        &self.spec,
                        &problem.what,
                        calib,
                    );
                    let met = coord_metrics();
                    let tx_bytes = if shipped_x { &met.tx_acts } else { &met.tx_gram };
                    tx_bytes.add(payload.len() as u64);
                    if let Err(e) = write_frame(&mut writer, tag::SOLVE, &payload) {
                        lock(&self.pending).push_front(job);
                        if in_flight.is_empty() {
                            if from_cache {
                                // stale parked connection: one free
                                // redial, no attempt burned
                                continue 'idle;
                            }
                            // a saturated worker may have refused us with a
                            // BUSY still sitting in our receive buffer (its
                            // refusal drain is bounded, so a huge frame can
                            // fail the write first) — prefer that
                            // classification over a hard failure
                            let refusal = read_frame_deadline(
                                &mut reader,
                                self.cfg.max_frame_bytes,
                                None,
                                Some(Duration::from_secs(1)),
                                Some(Duration::from_secs(5)),
                            );
                            if let Ok(FrameRead::Frame { tag: tag::BUSY, .. }) = refusal {
                                let since = *busy_since.get_or_insert_with(Instant::now);
                                if since.elapsed() >= self.cfg.busy_patience {
                                    self.leave(
                                        member,
                                        format!(
                                            "{addr}: busy (at capacity) for {:.1}s",
                                            since.elapsed().as_secs_f64()
                                        ),
                                    );
                                    return;
                                }
                                std::thread::sleep(self.cfg.retry_backoff);
                                continue 'idle;
                            }
                            // nothing owed on this connection: a failed
                            // write really is a broken worker link
                            if self.written_off(member, &mut attempts, false, || {
                                format!("{addr}: send failed: {e}")
                            }) {
                                return;
                            }
                            continue 'idle;
                        }
                        // backpressure, not failure: the worker is solving
                        // and not reading — drain its responses instead
                        can_send = false;
                        break;
                    }
                    sent_at.push((job.slot, Instant::now()));
                    in_flight.push_back(job);
                    last_owned_signal = Instant::now();
                }
                if in_flight.is_empty() {
                    if !can_send {
                        // write side poisoned (possibly partial frame) but
                        // fully drained: replace the connection; attempts
                        // was reset by the drained responses
                        continue 'idle;
                    }
                    if lock(&self.pending).is_empty() {
                        // queue drained and nothing owed: park the healthy
                        // connection and go idle until work arrives
                        *lock(&member.conn) = Some(writer);
                        continue 'idle;
                    }
                    continue;
                }
                // heartbeats arrive every couple of seconds during a solve,
                // so owned-signal silence beyond the grace means a dead
                // worker — far tighter than the idle ceiling kept for
                // tuned-down `--shard-idle` links. The budget is the
                // *remaining* grace since the last owned signal, so
                // unowned frames (which complete a read without renewing
                // the clock) cannot stretch it; the per-frame wall-clock
                // deadline (at least the idle ceiling, so a huge
                // legitimate RESULT still has the full `--shard-idle`
                // window to transfer) stops a peer from pinning us with
                // one never-completing dribbled frame.
                let silence_budget = self.cfg.heartbeat_grace.min(self.cfg.idle_timeout);
                let remaining = silence_budget.saturating_sub(last_owned_signal.elapsed());
                let read = if remaining.is_zero() {
                    // grace exhausted across reads (e.g. a stream of
                    // unowned heartbeats): same as a mid-solve hang
                    Err(anyhow::anyhow!(
                        "no owned result/heartbeat for {:.1}s",
                        silence_budget.as_secs_f64()
                    ))
                } else {
                    read_frame_deadline(
                        &mut reader,
                        self.cfg.max_frame_bytes,
                        Some(&self.shutdown),
                        Some(remaining),
                        Some(self.cfg.idle_timeout.max(remaining)),
                    )
                };
                match read {
                    Ok(FrameRead::Frame { tag: tag::RESULT, payload }) => {
                        match wire::SolveResponse::decode(&payload) {
                            Ok(resp) => {
                                let pos = in_flight
                                    .iter()
                                    .position(|j| j.slot as u64 == resp.job);
                                if let Some(p) = pos {
                                    let Some(job) = in_flight.remove(p) else { continue };
                                    if let Some(sp) =
                                        sent_at.iter().position(|(s, _)| *s == job.slot)
                                    {
                                        rpc_secs.observe(
                                            sent_at.remove(sp).1.elapsed().as_secs_f64(),
                                        );
                                    }
                                    self.deliver(member, &job, resp);
                                    // a delivered solve proves the worker
                                    // healthy; give transient failures a
                                    // fresh retry budget and treat the
                                    // connection as established (no longer
                                    // a stale-cache suspect)
                                    attempts = 0;
                                    busy_since = None;
                                    from_cache = false;
                                    last_owned_signal = Instant::now();
                                } else {
                                    // desynced or corrupt response: drop
                                    // the connection and reroute everything
                                    // in flight
                                    self.requeue(member, &mut in_flight);
                                    if self.written_off(member, &mut attempts, from_cache, || {
                                        format!("{addr}: answered unknown job {}", resp.job)
                                    }) {
                                        return;
                                    }
                                    continue 'idle;
                                }
                            }
                            Err(e) => {
                                self.requeue(member, &mut in_flight);
                                if self.written_off(member, &mut attempts, from_cache, || {
                                    format!("{addr}: bad response: {e}")
                                }) {
                                    return;
                                }
                                continue 'idle;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::HEARTBEAT, payload }) => {
                        // liveness beacon: the solve is progressing. Only a
                        // beat for a job we own proves *our* channel (a
                        // desynced peer echoing someone else's beat does
                        // not). A beat renews the silence clock, clears the
                        // stale-cache/busy suspicion, and raises the
                        // member's solve-time estimate when the in-progress
                        // solve already exceeds it — but deliberately NOT
                        // the reconnect-attempt budget: only a *delivered
                        // result* does that, so a worker that beats once
                        // and crashes on every connection still exhausts
                        // `max_attempts` instead of looping forever.
                        if let Ok(hb) = wire::decode_heartbeat(&payload) {
                            if in_flight.iter().any(|j| j.slot as u64 == hb.job) {
                                busy_since = None;
                                from_cache = false;
                                last_owned_signal = Instant::now();
                                member.raise_ewma_floor(hb.elapsed_ms as f64 / 1000.0);
                                if let Some(board) = self.board() {
                                    board.note_heartbeat(addr, &hb);
                                }
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::ERROR, payload }) => {
                        // an ERROR echoing one of OUR in-flight jobs is a
                        // deterministic solver failure: retrying on another
                        // worker would fail identically — abort that job's
                        // block. The member survives (nothing is wrong with
                        // the transport); its remaining in-flight jobs stay
                        // owed and their late results land in the dead
                        // block harmlessly. An ERROR for a job we don't own
                        // (the worker's u64::MAX protocol sentinel, or a
                        // desynced peer) is a transport fault: reroute and
                        // retry.
                        match wire::decode_error(&payload) {
                            Ok((jobid, m)) => {
                                let pos = in_flight
                                    .iter()
                                    .position(|j| j.slot as u64 == jobid);
                                if let Some(p) = pos {
                                    let Some(job) = in_flight.remove(p) else { continue };
                                    sent_at.retain(|(s, _)| *s != job.slot);
                                    let msg = format!("worker {addr}, job {jobid}: {m}");
                                    let mut fatal = lock(&job.block.fatal);
                                    if fatal.is_none() {
                                        *fatal = Some(msg);
                                    }
                                    drop(fatal);
                                    last_owned_signal = Instant::now();
                                } else {
                                    self.requeue(member, &mut in_flight);
                                    if self.written_off(member, &mut attempts, from_cache, || {
                                        format!("{addr}: protocol error: {m}")
                                    }) {
                                        return;
                                    }
                                    continue 'idle;
                                }
                            }
                            Err(e) => {
                                self.requeue(member, &mut in_flight);
                                self.leave(member, format!("{addr}: undecodable error: {e}"));
                                return;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::BUSY, .. }) => {
                        // worker at its connection cap: a healthy-but-full
                        // pool member, so it spends its own (much longer)
                        // patience budget, not the hard-failure attempts
                        self.requeue(member, &mut in_flight);
                        let since = *busy_since.get_or_insert_with(Instant::now);
                        if since.elapsed() >= self.cfg.busy_patience {
                            self.leave(
                                member,
                                format!(
                                    "{addr}: busy (at capacity) for {:.1}s",
                                    since.elapsed().as_secs_f64()
                                ),
                            );
                            return;
                        }
                        std::thread::sleep(self.cfg.retry_backoff);
                        continue 'idle;
                    }
                    Ok(FrameRead::Frame { tag, .. }) => {
                        self.requeue(member, &mut in_flight);
                        self.leave(member, format!("{addr}: unexpected frame tag {tag}"));
                        return;
                    }
                    Ok(FrameRead::Shutdown) => {
                        // close() raised the pool flag mid-read
                        self.requeue(member, &mut in_flight);
                        return;
                    }
                    Ok(FrameRead::Eof) => {
                        // worker closed the connection mid-solve: reroute
                        self.requeue(member, &mut in_flight);
                        if self.written_off(member, &mut attempts, from_cache, || {
                            format!("{addr}: disconnected mid-solve")
                        }) {
                            return;
                        }
                        continue 'idle;
                    }
                    Err(e) => {
                        // keep the real cause: "no owned result/heartbeat
                        // for Ns" (missed-beat detection on a still-open
                        // connection) reads very differently from a
                        // dropped connection when debugging a pool
                        self.requeue(member, &mut in_flight);
                        if self.written_off(member, &mut attempts, from_cache, || {
                            format!("{addr}: {e}")
                        }) {
                            return;
                        }
                        continue 'idle;
                    }
                }
            }
        }
    }
}

/// A pruning [`Engine`] that fans layer solves across an elastic pool of
/// remote workers; dispatcher threads and connections live for the whole
/// run and are released by [`ShardedEngine::close`].
pub struct ShardedEngine {
    /// Seed worker addresses, turned into pool members at the first
    /// dispatch (and again after a `close`).
    workers: Vec<String>,
    pool: Arc<Pool>,
    /// The registration listener's thread, kept out of `Pool::threads`
    /// so it never tries to join itself at close.
    registrar: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ShardedEngine {
    /// `workers` are `host:port` addresses of running `alps worker`
    /// processes (at least one — further workers can REGISTER later).
    pub fn new(spec: MethodSpec, workers: Vec<String>) -> Result<ShardedEngine> {
        Self::with_config(spec, workers, ShardedConfig::default())
    }

    pub fn with_config(
        spec: MethodSpec,
        workers: Vec<String>,
        cfg: ShardedConfig,
    ) -> Result<ShardedEngine> {
        if workers.is_empty() {
            bail!("ShardedEngine needs at least one worker address");
        }
        let cfg = ShardedConfig {
            max_outstanding: cfg.max_outstanding.max(1),
            max_attempts: cfg.max_attempts.max(1),
            ..cfg
        };
        Ok(ShardedEngine {
            workers,
            pool: Arc::new(Pool::new(spec, cfg)),
            registrar: Mutex::new(None),
        })
    }

    /// Parse a CLI `host:port,host:port` list.
    pub fn from_flag(spec: MethodSpec, flag: &str) -> Result<ShardedEngine> {
        let workers: Vec<String> = flag
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        Self::new(spec, workers)
    }

    pub fn workers(&self) -> &[String] {
        &self.workers
    }

    /// Surface worker heartbeats and fleet membership on a status board
    /// (the `--status-addr` endpoint includes per-worker beat counts, the
    /// fleet-size series, and join/leave events in its snapshot).
    pub fn set_status_board(&mut self, board: Arc<StatusBoard>) {
        *lock(&self.pool.board) = Some(board);
    }

    /// Start accepting [`tag::REGISTER`] frames on `addr` so workers can
    /// join the fleet mid-run (`alps worker --register <this addr>`).
    /// Returns the bound address (useful with a `:0` port). The listener
    /// runs until [`ShardedEngine::close`].
    pub fn listen_for_registrations(&self, addr: &str) -> Result<String> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding registration endpoint {addr}"))?;
        let local = listener
            .local_addr()
            .context("registration endpoint local addr")?
            .to_string();
        listener
            .set_nonblocking(true)
            .context("registration endpoint nonblocking")?;
        let pool = self.pool.clone();
        let handle = std::thread::spawn(move || registration_loop(&pool, &listener));
        *lock(&self.registrar) = Some(handle);
        Ok(local)
    }

    /// Turn the seed worker list into pool members (once per pool life;
    /// `close` resets, so the next solve reseeds and redials).
    fn ensure_running(&self) {
        if self.pool.seeded.swap(true, Ordering::SeqCst) {
            return;
        }
        for addr in &self.workers {
            self.pool.add_member(addr);
        }
    }

    /// Stop the pool: raise the shutdown flag, join the registration
    /// listener and every dispatcher thread, and drop all membership
    /// state (including parked connections). Safe at any point and
    /// idempotent; a later solve reseeds the fleet from the worker list
    /// and redials. The session calls this when a run finishes so worker
    /// slots free immediately instead of waiting for the engine to drop.
    pub fn close(&self) {
        self.pool.shutdown.store(true, Ordering::SeqCst);
        // the registrar first, so no new dispatcher spawns mid-close
        if let Some(handle) = lock(&self.registrar).take() {
            let _ = handle.join();
        }
        loop {
            let handles: Vec<_> = std::mem::take(&mut *lock(&self.pool.threads));
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
        lock(&self.pool.members).clear();
        lock(&self.pool.pending).clear();
        coord_metrics().fleet.set(0.0);
        self.pool.seeded.store(false, Ordering::SeqCst);
        self.pool.shutdown.store(false, Ordering::SeqCst);
    }

    /// Fan the problems across the pool as owned jobs; results are
    /// positional. One deep problem clone per layer is the price of the
    /// borrow-free pool (the matrices still cross the wire at most once).
    fn dispatch(
        &self,
        problems: &[&LayerProblem],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        if problems.is_empty() {
            return Ok(Vec::new());
        }
        self.ensure_running();
        let block = Arc::new(BlockState {
            results: Mutex::new((0..problems.len()).map(|_| None).collect()),
            unsolved: AtomicUsize::new(problems.len()),
            fatal: Mutex::new(None),
        });
        {
            let mut pending = lock(&self.pool.pending);
            for (slot, p) in problems.iter().enumerate() {
                pending.push_back(Arc::new(OwnedJob {
                    slot,
                    target,
                    problem: Arc::new((*p).clone()),
                    block: block.clone(),
                }));
            }
        }
        loop {
            if block.unsolved.load(Ordering::SeqCst) == 0 {
                break;
            }
            let fatal = lock(&block.fatal).clone();
            if let Some(msg) = fatal {
                self.drain_block(&block);
                bail!("sharded solve failed: {msg}");
            }
            if self.pool.live_members() == 0 {
                let unsolved = block.unsolved.load(Ordering::SeqCst);
                if unsolved > 0 {
                    self.drain_block(&block);
                    let errors = std::mem::take(&mut *lock(&self.pool.worker_errors));
                    bail!(
                        "{unsolved} of {} layers unsolved — every worker failed: [{}]",
                        problems.len(),
                        errors.join("; ")
                    );
                }
            }
            std::thread::sleep(WAIT_POLL);
        }
        let errors = std::mem::take(&mut *lock(&self.pool.worker_errors));
        if !errors.is_empty() {
            // the block completed, but part of the fleet died along the way
            eprintln!("[sharded] degraded pool: {}", errors.join("; "));
        }
        let results = std::mem::take(&mut *lock(&block.results));
        // `unsolved == 0` above: every slot is Some, so flatten loses nothing
        Ok(results.into_iter().flatten().collect())
    }

    /// Remove a failed block's unassigned jobs from the shared queue so
    /// they stop competing with the next block's work. Its in-flight jobs
    /// stay with their members: late results land in the dead block
    /// harmlessly (the `Arc` keeps it alive), which keeps every
    /// connection's request/response stream in sync.
    fn drain_block(&self, block: &Arc<BlockState>) {
        lock(&self.pool.pending).retain(|j| !Arc::ptr_eq(&j.block, block));
    }
}

impl Engine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded({})", self.pool.spec.label())
    }

    fn config_digest(&self) -> String {
        // identical to NativeEngine's digest for the same spec, and the
        // worker list is deliberately excluded: neither the pool shape
        // nor remoting (nor where the gram is computed) changes a single
        // bit of the results, so checkpoints resume across pool changes
        // AND across the native/sharded boundary
        format!("{:?}", self.pool.spec)
    }

    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult> {
        Ok(self.dispatch(&[problem], target)?.remove(0))
    }

    fn solve_block(
        &self,
        jobs: &[LayerJob],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        let problems: Vec<&LayerProblem> = jobs.iter().map(|j| &j.problem).collect();
        self.dispatch(&problems, target)
    }

    fn close(&self) {
        ShardedEngine::close(self)
    }
}

impl Drop for ShardedEngine {
    /// An engine dropped without an explicit `close` must not leak
    /// spinning dispatcher threads.
    fn drop(&mut self) {
        ShardedEngine::close(self);
    }
}

/// Accept loop for the registration endpoint: non-blocking accepts,
/// polled against the pool's shutdown flag so `close` can join it.
fn registration_loop(pool: &Arc<Pool>, listener: &TcpListener) {
    while !pool.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => handle_registration(pool, stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(WAIT_POLL);
            }
            Err(_) => std::thread::sleep(WAIT_POLL),
        }
    }
}

/// One registration handshake: read a REGISTER frame carrying the
/// worker's advertised serve address, add it to the fleet, and ack by
/// echoing the frame back (the worker's dialer retries until it sees the
/// echo). Malformed or non-REGISTER traffic is dropped silently — this
/// endpoint changes fleet membership, so it answers nothing else.
fn handle_registration(pool: &Arc<Pool>, stream: TcpStream) {
    let mut stream = stream;
    // the listener is non-blocking; the conversation must not be
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    if stream.set_read_timeout(Some(Duration::from_millis(200))).is_err() {
        return;
    }
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    // a registration frame is tiny (an address string); bound it hard
    let read = read_frame_deadline(
        &mut stream,
        4096,
        Some(&pool.shutdown),
        Some(Duration::from_secs(10)),
        Some(Duration::from_secs(10)),
    );
    let Ok(FrameRead::Frame { tag: tag::REGISTER, payload }) = read else {
        return;
    };
    let Ok(addr) = wire::decode_register(&payload) else {
        return;
    };
    pool.add_member(&addr);
    let _ = write_frame(&mut stream, tag::REGISTER, &payload);
}

/// Resolve `addr` and try **every** candidate address before giving up —
/// a dual-stack hostname that resolves IPv6-first must still reach a
/// worker listening on IPv4 (and vice versa) without burning a reconnect
/// attempt per address family.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    use std::net::ToSocketAddrs as _;
    let candidates: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address '{addr}'"))?
        .collect();
    connect_candidates(&candidates, timeout)
        .with_context(|| format!("connecting to worker {addr}"))
}

/// Dial the candidates in resolution order; first success wins, the last
/// failure is reported when none do.
fn connect_candidates(candidates: &[SocketAddr], timeout: Duration) -> Result<TcpStream> {
    if candidates.is_empty() {
        bail!("address resolved to nothing");
    }
    let mut last: Option<(SocketAddr, std::io::Error)> = None;
    for sa in candidates {
        match TcpStream::connect_timeout(sa, timeout) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                // short socket timeout: read_frame loops on ticks against
                // the heartbeat-grace / idle budgets
                stream.set_read_timeout(Some(Duration::from_millis(200)))?;
                stream.set_write_timeout(Some(Duration::from_secs(10)))?;
                return Ok(stream);
            }
            Err(e) => last = Some((*sa, e)),
        }
    }
    match last {
        Some((sa, e)) => {
            bail!("no candidate reachable ({} tried, last {sa}: {e})", candidates.len())
        }
        None => bail!("no candidate reachable (0 tried)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::worker::{register_with_coordinator, Worker, WorkerConfig};
    use crate::pruning::NativeEngine;
    use std::net::TcpListener;

    fn jobs(n: usize, seed: u64) -> Vec<LayerJob> {
        (0..n)
            .map(|i| LayerJob {
                name: format!("blocks.0.l{i}"),
                problem: random_problem(14, 7, 50, seed + i as u64),
            })
            .collect()
    }

    fn quick_cfg() -> ShardedConfig {
        ShardedConfig {
            max_attempts: 2,
            connect_timeout: Duration::from_millis(500),
            idle_timeout: Duration::from_secs(30),
            heartbeat_grace: Duration::from_secs(30),
            retry_backoff: Duration::from_millis(10),
            busy_patience: Duration::from_millis(80),
            ..Default::default()
        }
    }

    fn spawn_worker() -> (String, std::sync::Arc<Worker>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
        let w = worker.clone();
        std::thread::spawn(move || {
            let _ = w.serve(listener);
        });
        (addr, worker)
    }

    #[test]
    fn sharded_block_matches_native_bitwise() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let spec = MethodSpec::Wanda;
            let js = jobs(5, 100);
            let target = SparsityTarget::Unstructured(0.6);
            let sharded =
                ShardedEngine::with_config(spec.clone(), vec![addr.clone()], quick_cfg())
                    .unwrap();
            let remote = sharded.solve_block(&js, target).unwrap();
            let local = NativeEngine::new(spec).solve_block(&js, target).unwrap();
            assert_eq!(remote.len(), local.len());
            for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
                assert_eq!(r.w, l.w, "job {i} differs from native");
                assert_eq!(r.worker.as_deref(), Some(addr.as_str()));
            }
            sharded.close();
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn shipped_activations_match_native_bitwise() {
        // --ship-activations path: X travels, the worker grams it. The
        // problems must be wide (rows < n_in) or the dispatcher would
        // rightly pick the smaller gram encoding instead.
        let (addr, worker) = spawn_worker();
        let spec = MethodSpec::SparseGpt(Default::default());
        let js: Vec<LayerJob> = (0..4)
            .map(|i| LayerJob {
                name: format!("blocks.0.wide{i}"),
                problem: random_problem(24, 8, 10, 500 + i as u64),
            })
            .collect();
        let target = SparsityTarget::Unstructured(0.55);
        let sharded = ShardedEngine::with_config(
            spec.clone(),
            vec![addr],
            ShardedConfig { ship_activations: true, ..quick_cfg() },
        )
        .unwrap();
        let remote = sharded.solve_block(&js, target).unwrap();
        let local = NativeEngine::new(spec).solve_block(&js, target).unwrap();
        for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
            assert_eq!(r.w, l.w, "job {i} differs with worker-side gram");
        }
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn connections_persist_across_block_solves_until_close() {
        let (addr, worker) = spawn_worker();
        let sharded = ShardedEngine::with_config(
            MethodSpec::Magnitude,
            vec![addr],
            quick_cfg(),
        )
        .unwrap();
        let target = SparsityTarget::Unstructured(0.5);
        // three "blocks" through one engine: one dial total
        for seed in [0u64, 10, 20] {
            sharded.solve_block(&jobs(3, seed), target).unwrap();
        }
        assert_eq!(
            worker.connections_accepted(),
            1,
            "long-lived pool must reuse its connection across blocks"
        );
        // close() tears the pool down; the next solve reseeds and redials
        sharded.close();
        sharded.solve_block(&jobs(2, 30), target).unwrap();
        assert_eq!(worker.connections_accepted(), 2);
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn stale_parked_connection_gets_a_free_redial() {
        // a parked connection whose peer died between blocks must not
        // burn a retry attempt: with max_attempts=1 the solve still
        // succeeds because staleness redials for free
        let (addr, worker) = spawn_worker();
        let sharded = ShardedEngine::with_config(
            MethodSpec::Magnitude,
            vec![addr],
            ShardedConfig {
                max_attempts: 1,
                // if the dead peer never RSTs, the grace (not a hang)
                // converts its silence into the free redial
                heartbeat_grace: Duration::from_millis(300),
                ..quick_cfg()
            },
        )
        .unwrap();
        let target = SparsityTarget::Unstructured(0.5);
        sharded.solve_block(&jobs(2, 40), target).unwrap();
        // sabotage the parked connection: swap in a stream whose peer is
        // already gone (bound listener dropped after the connect)
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let peer = l.local_addr().unwrap();
            let s = TcpStream::connect(peer).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            s.set_write_timeout(Some(Duration::from_secs(1))).unwrap();
            drop(l);
            s
        };
        {
            let members = lock(&sharded.pool.members);
            *lock(&members[0].conn) = Some(dead);
        }
        // would fail with max_attempts=1 if staleness cost an attempt
        sharded.solve_block(&jobs(2, 50), target).unwrap();
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn all_workers_dead_is_an_error_not_a_hang() {
        // bind then immediately drop: connection refused at that port
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![dead], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(2, 200), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("2 of 2 layers unsolved"), "{err}");
    }

    #[test]
    fn connect_tries_every_resolved_candidate() {
        // first candidate dead, second alive: the dial must fall through
        // to the live one instead of failing the attempt outright (the
        // dual-stack hostname case, pinned here with explicit addresses)
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let live = live_listener.local_addr().unwrap();
        let stream =
            connect_candidates(&[dead, live], Duration::from_millis(500)).unwrap();
        assert_eq!(stream.peer_addr().unwrap(), live);
        // no candidates / all dead errors mention the count
        assert!(connect_candidates(&[], Duration::from_millis(100)).is_err());
        let err = connect_candidates(&[dead], Duration::from_millis(100))
            .unwrap_err()
            .to_string();
        assert!(err.contains("1 tried"), "{err}");
    }

    #[test]
    fn solver_error_aborts_instead_of_retrying() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            // structured ALPS rejects N:M targets deterministically
            let sharded = ShardedEngine::with_config(
                MethodSpec::AlpsStructured(Default::default()),
                vec![addr],
                quick_cfg(),
            )
            .unwrap();
            let err = sharded
                .solve_block(&jobs(2, 300), SparsityTarget::NM { n: 2, m: 4 })
                .unwrap_err()
                .to_string();
            assert!(err.contains("sharded solve failed"), "{err}");
            assert!(err.contains("N:M"), "{err}");
            sharded.close();
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn busy_worker_is_retryable_not_fatal() {
        // a BUSY refusal must never abort the run the way a solver error
        // does — it exhausts its own patience budget (not the hard-failure
        // attempts) and the worker is written off, not the block failed
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let fake = std::thread::spawn(move || {
            // a permanently-saturated worker: BUSY on every connection
            listener.set_nonblocking(true).unwrap();
            while !done2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        let _ = conn.set_nonblocking(false);
                        let _ = write_frame(
                            &mut conn,
                            tag::BUSY,
                            &wire::encode_error(0, "worker connection limit reached (1)"),
                        );
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![addr], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(1, 400), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unsolved"), "not fatal, just written off: {err}");
        assert!(err.contains("busy"), "{err}");
        done.store(true, Ordering::SeqCst);
        fake.join().unwrap();
    }

    #[test]
    fn empty_workers_rejected_and_flag_parses() {
        assert!(ShardedEngine::new(MethodSpec::Wanda, vec![]).is_err());
        let e = ShardedEngine::from_flag(MethodSpec::Wanda, "a:1, b:2,,").unwrap();
        let got: Vec<&str> = e.workers().iter().map(String::as_str).collect();
        assert_eq!(got, vec!["a:1", "b:2"]);
        assert_eq!(e.label(), "sharded(wanda)");
        assert!(ShardedEngine::from_flag(MethodSpec::Wanda, " ,").is_err());
    }

    #[test]
    fn slowest_member_takes_smallest_pending_layer() {
        // pure dequeue-policy check, no threads: hand-build a fleet with
        // solve-time estimates and a queue of differently-sized layers
        let pool = Arc::new(Pool::new(MethodSpec::Magnitude, quick_cfg()));
        let fast = Arc::new(Member::new("fast:1".into()));
        let slow = Arc::new(Member::new("slow:2".into()));
        fast.fold_ewma(0.1);
        slow.fold_ewma(9.0);
        lock(&pool.members).extend([fast.clone(), slow.clone()]);
        let block = Arc::new(BlockState {
            results: Mutex::new((0..3).map(|_| None).collect()),
            unsolved: AtomicUsize::new(3),
            fatal: Mutex::new(None),
        });
        let target = SparsityTarget::Unstructured(0.5);
        let push = |slot: usize, n_in: usize| {
            lock(&pool.pending).push_back(Arc::new(OwnedJob {
                slot,
                target,
                problem: Arc::new(random_problem(n_in, 4, 10, slot as u64)),
                block: block.clone(),
            }));
        };
        push(0, 24);
        push(1, 6);
        push(2, 16);
        // the slow member skips the queue head for the smallest layer
        assert_eq!(pool.take_job(&slow).unwrap().slot, 1);
        // the fast member just takes the front
        assert_eq!(pool.take_job(&fast).unwrap().slot, 0);
        // with one job left there is no choice (len > 1 guard)
        assert_eq!(pool.take_job(&slow).unwrap().slot, 2);
        // jobs of an aborted block are dropped on sight
        let failed = Arc::new(BlockState {
            results: Mutex::new(vec![None]),
            unsolved: AtomicUsize::new(1),
            fatal: Mutex::new(Some("boom".into())),
        });
        lock(&pool.pending).push_back(Arc::new(OwnedJob {
            slot: 0,
            target,
            problem: Arc::new(random_problem(6, 4, 10, 9)),
            block: failed,
        }));
        assert!(pool.take_job(&fast).is_none());
        assert!(lock(&pool.pending).is_empty());
    }

    #[test]
    fn register_endpoint_adds_members_mid_run() {
        let (addr_a, worker_a) = spawn_worker();
        let sharded = ShardedEngine::with_config(
            MethodSpec::Wanda,
            vec![addr_a.clone()],
            quick_cfg(),
        )
        .unwrap();
        let reg = sharded.listen_for_registrations("127.0.0.1:0").unwrap();
        let target = SparsityTarget::Unstructured(0.6);
        let js = jobs(3, 700);
        let local = NativeEngine::new(MethodSpec::Wanda).solve_block(&js, target).unwrap();
        let remote = sharded.solve_block(&js, target).unwrap();
        for (r, l) in remote.iter().zip(&local) {
            assert_eq!(r.w, l.w);
        }
        // join a second worker mid-run through the REGISTER endpoint; the
        // ack only comes back after the member is in the fleet
        let (addr_b, worker_b) = spawn_worker();
        let stop = AtomicBool::new(false);
        register_with_coordinator(&reg, &addr_b, &stop).unwrap();
        assert_eq!(sharded.pool.live_members(), 2);
        // re-registering a live address is idempotent
        register_with_coordinator(&reg, &addr_b, &stop).unwrap();
        assert_eq!(sharded.pool.live_members(), 2);
        // the grown fleet still reassembles bit-identically
        let js2 = jobs(6, 800);
        let local2 = NativeEngine::new(MethodSpec::Wanda).solve_block(&js2, target).unwrap();
        let remote2 = sharded.solve_block(&js2, target).unwrap();
        for (i, (r, l)) in remote2.iter().zip(&local2).enumerate() {
            assert_eq!(r.w, l.w, "job {i} differs after the fleet grew");
            let w = r.worker.as_deref().unwrap();
            assert!(w == addr_a || w == addr_b, "unknown solver {w}");
        }
        sharded.close();
        worker_a.request_shutdown();
        worker_b.request_shutdown();
    }
}
