//! Coordinator-side dispatcher for distributed pruning: a
//! [`ShardedEngine`] implementing [`crate::pruning::Engine`] that ships
//! [`LayerProblem`]s to a pool of `alps worker` processes over the binary
//! frame protocol ([`crate::pruning::wire`], version 2) and reassembles
//! results deterministically.
//!
//! Design:
//!
//! * **One dispatcher thread per worker**, all draining one shared job
//!   queue — a fast worker naturally takes more layers (work stealing by
//!   construction), and layer order never matters because results land in
//!   a slot indexed by job position. The threads are scoped per block
//!   solve (they borrow the block's problems — zero copies); what
//!   persists across blocks is the expensive part, the **connections**.
//! * **Persistent worker pool**: each worker's TCP connection is parked
//!   in a per-slot cache when a block finishes and picked up again by the
//!   next block's dispatcher, so an N-block run dials each worker once,
//!   not N times. A parked connection that went stale between blocks
//!   (worker restarted, NAT timeout) gets one free redial — staleness is
//!   not a worker failure and never burns a retry attempt.
//!   [`ShardedEngine::close`] drops the cached connections explicitly
//!   (the session calls it when a run finishes; dropping the engine does
//!   the same).
//! * **Heartbeat liveness**: protocol-v2 workers emit a
//!   [`tag::HEARTBEAT`] frame every couple of seconds while solving, so
//!   *any* silence longer than [`ShardedConfig::heartbeat_grace`]
//!   (default 30 s) means the worker is gone — not merely slow — and its
//!   in-flight jobs reroute immediately instead of waiting out the
//!   [`ShardedConfig::idle_timeout`] (default 600 s, kept as the
//!   wall-clock ceiling on any single frame transfer, which also defeats
//!   byte-dribbling peers). Beats renew the silence clock (only a
//!   delivered result renews the reconnect-attempt budget, so a
//!   beat-then-crash worker still exhausts its attempts), and they
//!   surface on the status endpoint when a [`StatusBoard`] is attached.
//! * **Per-worker outstanding-request limit**
//!   ([`ShardedConfig::max_outstanding`]): each connection pipelines a
//!   bounded number of in-flight solves, enough to hide the round trip
//!   without buffering a whole block on one worker.
//! * **Activation shipping** ([`ShardedConfig::ship_activations`]): when
//!   the layer problem retains its calibration rows X `[n, n_in]` and X
//!   is strictly smaller than the gram (`n < n_in`), the request ships X
//!   instead of H `[n_in, n_in]` and the worker builds H itself with the
//!   same deterministic kernel — O(n·n_in) wire bytes instead of
//!   O(n_in^2), a large cut for wide layers pruned from modest
//!   calibration sets, and never an inflation for narrow ones (the
//!   cheaper encoding is chosen per layer).
//! * **Retry on disconnect**: a failed connect, a broken connection, or a
//!   hung worker requeues that worker's in-flight jobs at the *front* of
//!   the queue (another worker picks them up next) and the worker gets a
//!   bounded number of reconnect attempts
//!   ([`ShardedConfig::max_attempts`]). The run completes as long as one
//!   worker survives; only when every pool member is gone do unsolved
//!   layers fail the block.
//! * **Solver errors are not retried**: a worker answering `tag::ERROR`
//!   for a job this connection owns hit a deterministic failure (bad
//!   target for the method, degenerate problem) that would fail
//!   identically anywhere, so the whole block aborts with that message.
//!   Transport-level refusals (`tag::BUSY` at the connection cap, or an
//!   ERROR carrying the worker's protocol sentinel instead of an owned
//!   job id) stay retryable.
//! * **Observability**: the dispatcher feeds the process-global
//!   [`crate::obs`] registry — per-worker RPC latency histograms
//!   (`alps_coord_rpc_seconds{worker=...}`), burned reconnect attempts
//!   (`alps_coord_retries_total`), rerouted in-flight jobs
//!   (`alps_coord_reroutes_total`), and request payload bytes split by
//!   calibration encoding (`alps_coord_wire_tx_bytes_total{calib=...}` —
//!   the live measure of what activation shipping saves). All recording
//!   is lock-free and off the result path: instrumentation cannot change
//!   a bit of the reassembled weights.
//! * **Bit-identical results**: matrices travel bit-exactly
//!   (`to_le_bytes` round-trip), the worker rebuilds the problem with the
//!   same deterministic kernels (including the gram, when activations are
//!   shipped), and reassembly is positional — a sharded run equals a
//!   [`NativeEngine`] run to the last bit (proven by
//!   `tests/integration_sharded.rs` and the CI smoke step).

use crate::config::SparsityTarget;
use crate::net::framing::{read_frame_deadline, write_frame, FrameRead};
use crate::net::lock;
use crate::obs::Counter;
use crate::pruning::engine::{Engine, LayerJob, LayerResult};
use crate::pruning::status::StatusBoard;
use crate::pruning::wire::{self, tag, CalibRef};
use crate::pruning::{LayerProblem, MethodSpec};
use anyhow::{bail, Context as _, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Dispatcher tuning knobs.
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    /// Pipelined in-flight solves per worker connection.
    pub max_outstanding: usize,
    /// Connect/reconnect attempts per worker before it is written off.
    pub max_attempts: usize,
    /// Largest accepted response frame.
    pub max_frame_bytes: usize,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// Legacy silence ceiling (`--shard-idle`). The read loop waits
    /// `heartbeat_grace.min(idle_timeout)` for the next byte, so with v2
    /// heartbeats the grace is the effective budget and this only still
    /// bites when configured *below* the grace; it survives so operators
    /// who tuned `--shard-idle` down keep their tighter bound.
    pub idle_timeout: Duration,
    /// A worker owing us results that sends *nothing* — no result, no
    /// heartbeat — for this long is dead; its in-flight jobs reroute
    /// immediately. Must comfortably exceed the pool's worker-side beat
    /// interval (`alps worker --heartbeat-secs`, default 2 s — the CLI
    /// enforces grace >= 15 s and beat <= 5 s so no legal pair can
    /// cross); a grace below the beat interval declares every healthy
    /// worker dead mid-solve.
    pub heartbeat_grace: Duration,
    /// Pause between reconnect attempts.
    pub retry_backoff: Duration,
    /// How long to keep retrying a worker that answers BUSY (at its
    /// connection cap) before writing it off. Separate from
    /// `max_attempts`: a saturated worker is healthy and a slot may free
    /// at any moment, so it gets far more patience than a broken one.
    pub busy_patience: Duration,
    /// Ship calibration activations X instead of the gram H whenever the
    /// layer problem retains them *and* X is strictly smaller
    /// (`rows < n_in`) — O(n·n_in) wire bytes instead of O(n_in^2) for
    /// wide layers, with the gram kept for layers where it wins; the
    /// worker rebuilds the identical H either way.
    pub ship_activations: bool,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            max_outstanding: 2,
            max_attempts: 3,
            max_frame_bytes: 1 << 30,
            connect_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(600),
            heartbeat_grace: Duration::from_secs(30),
            retry_backoff: Duration::from_millis(100),
            busy_patience: Duration::from_secs(60),
            ship_activations: false,
        }
    }
}

/// Poll interval while a drained-queue worker waits for possible
/// reroutes: a job is only truly gone once its result slot is filled, so
/// survivors linger until the whole block is solved (or failed).
const WAIT_POLL: Duration = Duration::from_millis(50);

/// Process-global coordinator counters: `(retries, reroutes, tx_gram,
/// tx_activations)`. Retries are burned reconnect attempts, reroutes are
/// in-flight jobs requeued off a failed worker, and the tx counters split
/// solve-request payload bytes by calibration encoding — the live view of
/// the activation-shipping trade the module doc describes.
fn coord_metrics() -> &'static (Counter, Counter, Counter, Counter) {
    static M: std::sync::OnceLock<(Counter, Counter, Counter, Counter)> =
        std::sync::OnceLock::new();
    M.get_or_init(|| {
        let r = crate::obs::global();
        let tx = "alps_coord_wire_tx_bytes_total";
        let tx_help = "solve-request payload bytes sent, by calibration encoding";
        (
            r.counter("alps_coord_retries_total", "worker reconnect attempts burned", &[]),
            r.counter("alps_coord_reroutes_total", "in-flight jobs requeued off a worker", &[]),
            r.counter(tx, tx_help, &[("calib", "gram")]),
            r.counter(tx, tx_help, &[("calib", "activations")]),
        )
    })
}

/// Shared dispatch state for one block solve. Holds borrowed problems —
/// the dispatcher never copies a layer's matrices except into the wire
/// encoding itself.
struct Dispatch<'j> {
    problems: &'j [&'j LayerProblem],
    target: SparsityTarget,
    /// Job indices not yet assigned (rerouted jobs return to the front).
    pending: Mutex<VecDeque<usize>>,
    /// One slot per job, positional — deterministic reassembly.
    results: Mutex<Vec<Option<LayerResult>>>,
    /// First deterministic solver error; aborts the block.
    fatal: Mutex<Option<String>>,
    /// Transport-level failure per written-off worker (diagnostics).
    worker_errors: Mutex<Vec<String>>,
}

impl Dispatch<'_> {
    fn all_solved(&self) -> bool {
        !lock(&self.results).iter().any(|r| r.is_none())
    }
}

/// A pruning [`Engine`] that fans layer solves across remote workers,
/// keeping its per-worker connections alive across block solves.
pub struct ShardedEngine {
    spec: MethodSpec,
    workers: Vec<String>,
    cfg: ShardedConfig,
    /// Per-worker parked connection, reused by the next block's
    /// dispatcher (same index as `workers`).
    conns: Vec<Mutex<Option<TcpStream>>>,
    /// Live-progress sink: heartbeats are reported here when attached.
    board: Option<Arc<StatusBoard>>,
}

impl ShardedEngine {
    /// `workers` are `host:port` addresses of running `alps worker`
    /// processes (at least one).
    pub fn new(spec: MethodSpec, workers: Vec<String>) -> Result<ShardedEngine> {
        Self::with_config(spec, workers, ShardedConfig::default())
    }

    pub fn with_config(
        spec: MethodSpec,
        workers: Vec<String>,
        cfg: ShardedConfig,
    ) -> Result<ShardedEngine> {
        if workers.is_empty() {
            bail!("ShardedEngine needs at least one worker address");
        }
        let cfg = ShardedConfig {
            max_outstanding: cfg.max_outstanding.max(1),
            max_attempts: cfg.max_attempts.max(1),
            ..cfg
        };
        let conns = workers.iter().map(|_| Mutex::new(None)).collect();
        Ok(ShardedEngine { spec, workers, cfg, conns, board: None })
    }

    /// Parse a CLI `host:port,host:port` list.
    pub fn from_flag(spec: MethodSpec, flag: &str) -> Result<ShardedEngine> {
        let workers: Vec<String> = flag
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        Self::new(spec, workers)
    }

    pub fn workers(&self) -> &[String] {
        &self.workers
    }

    /// Surface worker heartbeats on a status board (the `--status-addr`
    /// endpoint includes per-worker beat counts in its snapshot).
    pub fn set_status_board(&mut self, board: Arc<StatusBoard>) {
        self.board = Some(board);
    }

    /// Shared failure epilogue for every retryable connection-level
    /// fault in [`ShardedEngine::worker_loop`]: a stale parked connection
    /// redials for free; otherwise one reconnect attempt is consumed
    /// (with the configured backoff before the retry) and the worker is
    /// written off — `true` — once the budget is gone. Keeping the policy
    /// in one place keeps the six failure sites from drifting.
    fn written_off(
        &self,
        d: &Dispatch,
        attempts: &mut usize,
        from_cache: bool,
        error: impl FnOnce() -> String,
    ) -> bool {
        if from_cache {
            // stale parked connection (worker restarted or link timed out
            // between blocks): one free redial, no attempt burned
            return false;
        }
        *attempts += 1;
        coord_metrics().0.inc();
        if *attempts >= self.cfg.max_attempts {
            lock(&d.worker_errors).push(error());
            return true;
        }
        std::thread::sleep(self.cfg.retry_backoff);
        false
    }

    /// One worker's dispatch loop: connect (or reuse the parked
    /// connection), keep up to `max_outstanding` solves in flight,
    /// reroute on failure, park the connection again when the block is
    /// done.
    fn worker_loop(&self, widx: usize, d: &Dispatch) {
        let addr = &self.workers[widx];
        // registered once per worker address; lock-free to observe
        let rpc_secs = crate::obs::global().histogram(
            "alps_coord_rpc_seconds",
            "send-to-result latency of a remote layer solve",
            &[("worker", addr)],
            &crate::obs::LATENCY_EDGES,
        );
        let mut attempts = 0usize;
        // set at the first BUSY answer; cleared by any successful solve
        let mut busy_since: Option<std::time::Instant> = None;
        'reconnect: loop {
            if lock(&d.fatal).is_some() || d.all_solved() {
                return;
            }
            if lock(&d.pending).is_empty() {
                // unsolved layers are in flight on other workers; linger in
                // case one dies and reroutes them here
                std::thread::sleep(WAIT_POLL);
                continue 'reconnect;
            }
            // a connection parked by a previous block is reused; if it
            // went stale in between, its failure below redials for free
            // (`from_cache`) instead of burning an attempt
            let (stream, mut from_cache) = match lock(&self.conns[widx]).take() {
                Some(s) => (s, true),
                None => match connect(addr, self.cfg.connect_timeout) {
                    Ok(s) => (s, false),
                    Err(e) => {
                        if self.written_off(d, &mut attempts, false, || {
                            format!("{addr}: {e}")
                        }) {
                            return;
                        }
                        continue 'reconnect;
                    }
                },
            };
            let mut reader = match stream.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    lock(&d.worker_errors).push(format!("{addr}: clone failed: {e}"));
                    return;
                }
            };
            let mut writer = stream;
            // in-flight job indices, in send order
            let mut in_flight: VecDeque<usize> = VecDeque::new();
            // send instants for the RPC-latency histogram, keyed by job
            // index (tiny: bounded by max_outstanding). Dropped wholesale
            // with the connection on reroute — a rerouted job's latency
            // would measure the failure, not the solve.
            let mut sent_at: Vec<(usize, std::time::Instant)> = Vec::new();
            // last moment this worker proved it is working *for us*: a
            // successful send, an owned RESULT, or an owned HEARTBEAT.
            // Frames for jobs we don't own (a desynced or hostile peer
            // echoing someone else's beats) deliberately do NOT renew it —
            // otherwise such a peer could pin our in-flight jobs forever
            // without ever tripping the grace.
            let mut last_owned_signal = std::time::Instant::now();
            // cleared when a pipelined send stalls: a busy worker only
            // reads between solves, so a huge second frame can exceed the
            // socket buffer and the write timeout without anything being
            // wrong — stop sending, keep reading (the write may have been
            // partial, so the channel can't carry further requests), and
            // replace the connection once the in-flight drain completes
            let mut can_send = true;
            let requeue = |in_flight: &mut VecDeque<usize>| {
                if !in_flight.is_empty() {
                    coord_metrics().1.add(in_flight.len() as u64);
                    if let Some(board) = &self.board {
                        // whatever this worker was live-reporting is now
                        // abandoned: clear its "solving" status entry so a
                        // dead worker doesn't show as forever in-progress
                        board.note_worker_stalled(addr);
                    }
                }
                let mut pending = lock(&d.pending);
                // front of the queue: a surviving worker reroutes these
                // before taking fresh work
                while let Some(idx) = in_flight.pop_back() {
                    pending.push_front(idx);
                }
            };
            loop {
                if lock(&d.fatal).is_some() {
                    if in_flight.is_empty() {
                        // clean connection, nothing owed: park it for the
                        // next block (the run may continue past this
                        // block's failure handling)
                        *lock(&self.conns[widx]) = Some(writer);
                    }
                    requeue(&mut in_flight);
                    return;
                }
                // top up the pipeline
                while can_send && in_flight.len() < self.cfg.max_outstanding {
                    let Some(idx) = lock(&d.pending).pop_front() else { break };
                    let problem = d.problems[idx];
                    // borrow-encode: no deep copy of the (possibly huge)
                    // weight and calibration matrices just to serialize
                    // them; ship raw activations instead of the gram when
                    // configured, retained, and *strictly smaller* — for
                    // rows >= n_in the gram is the cheaper payload, so the
                    // flag picks the winning encoding per layer instead of
                    // inflating narrow layers
                    let calib = match (self.cfg.ship_activations, &problem.x) {
                        (true, Some(x)) if x.rows < problem.h.rows => {
                            CalibRef::Activations(x.as_ref())
                        }
                        _ => CalibRef::Gram(&problem.h),
                    };
                    let shipped_x = matches!(calib, CalibRef::Activations(_));
                    let payload = wire::encode_solve(
                        idx as u64,
                        d.target,
                        &self.spec,
                        &problem.what,
                        calib,
                    );
                    let met = coord_metrics();
                    let tx_bytes = if shipped_x { &met.3 } else { &met.2 };
                    tx_bytes.add(payload.len() as u64);
                    if let Err(e) = write_frame(&mut writer, tag::SOLVE, &payload) {
                        lock(&d.pending).push_front(idx);
                        if in_flight.is_empty() {
                            if from_cache {
                                // stale parked connection (worker restarted
                                // or link timed out between blocks): one
                                // free redial, no attempt burned
                                continue 'reconnect;
                            }
                            // a saturated worker may have refused us with a
                            // BUSY still sitting in our receive buffer (its
                            // refusal drain is bounded, so a huge frame can
                            // fail the write first) — prefer that
                            // classification over a hard failure
                            let refusal = read_frame_deadline(
                                &mut reader,
                                self.cfg.max_frame_bytes,
                                None,
                                Some(Duration::from_secs(1)),
                                Some(Duration::from_secs(5)),
                            );
                            if let Ok(FrameRead::Frame { tag: tag::BUSY, .. }) = refusal {
                                let since = *busy_since
                                    .get_or_insert_with(std::time::Instant::now);
                                if since.elapsed() >= self.cfg.busy_patience {
                                    lock(&d.worker_errors).push(format!(
                                        "{addr}: busy (at capacity) for {:.1}s",
                                        since.elapsed().as_secs_f64()
                                    ));
                                    return;
                                }
                                std::thread::sleep(self.cfg.retry_backoff);
                                continue 'reconnect;
                            }
                            // nothing owed on this connection: a failed
                            // write really is a broken worker link
                            if self.written_off(d, &mut attempts, false, || {
                                format!("{addr}: send failed: {e}")
                            }) {
                                return;
                            }
                            continue 'reconnect;
                        }
                        // backpressure, not failure: the worker is solving
                        // and not reading — drain its responses instead
                        can_send = false;
                        break;
                    }
                    in_flight.push_back(idx);
                    sent_at.push((idx, std::time::Instant::now()));
                    last_owned_signal = std::time::Instant::now();
                }
                if in_flight.is_empty() {
                    if !can_send {
                        // write side poisoned (possibly partial frame) but
                        // fully drained: replace the connection; attempts
                        // was reset by the drained responses
                        continue 'reconnect;
                    }
                    // queue drained and nothing owed to us — but jobs in
                    // flight on *other* workers may still reroute here, so
                    // only leave once every result slot is filled
                    if d.all_solved() || lock(&d.fatal).is_some() {
                        // park the healthy connection for the next block
                        *lock(&self.conns[widx]) = Some(writer);
                        return;
                    }
                    if lock(&d.pending).is_empty() {
                        std::thread::sleep(WAIT_POLL);
                    }
                    continue;
                }
                // heartbeats arrive every couple of seconds during a solve,
                // so owned-signal silence beyond the grace means a dead
                // worker — far tighter than the idle ceiling kept for
                // v1-era links. The budget is the *remaining* grace since
                // the last owned signal, so unowned frames (which complete
                // a read without renewing the clock) cannot stretch it;
                // the per-frame wall-clock deadline (at least the idle
                // ceiling, so a huge legitimate RESULT still has the full
                // `--shard-idle` window to transfer) stops a peer from
                // pinning us with one never-completing dribbled frame.
                let silence_budget = self.cfg.heartbeat_grace.min(self.cfg.idle_timeout);
                let remaining = silence_budget.saturating_sub(last_owned_signal.elapsed());
                let read = if remaining.is_zero() {
                    // grace exhausted across reads (e.g. a stream of
                    // unowned heartbeats): same as a mid-solve hang
                    Err(anyhow::anyhow!(
                        "no owned result/heartbeat for {:.1}s",
                        silence_budget.as_secs_f64()
                    ))
                } else {
                    read_frame_deadline(
                        &mut reader,
                        self.cfg.max_frame_bytes,
                        None,
                        Some(remaining),
                        Some(self.cfg.idle_timeout.max(remaining)),
                    )
                };
                match read {
                    Ok(FrameRead::Frame { tag: tag::RESULT, payload }) => {
                        match wire::SolveResponse::decode(&payload) {
                            Ok(resp) if in_flight.contains(&(resp.job as usize)) => {
                                let idx = resp.job as usize;
                                in_flight.retain(|&i| i != idx);
                                if let Some(p) = sent_at.iter().position(|(i, _)| *i == idx) {
                                    rpc_secs.observe(sent_at.remove(p).1.elapsed().as_secs_f64());
                                }
                                lock(&d.results)[idx] = Some(LayerResult {
                                    w: resp.w,
                                    secs: resp.secs,
                                    admm_iters: resp.admm_iters as usize,
                                    worker: Some(addr.to_string()),
                                });
                                // a delivered solve proves the worker
                                // healthy; give transient failures a fresh
                                // retry budget and treat the connection as
                                // established (no longer a stale-cache
                                // suspect)
                                attempts = 0;
                                busy_since = None;
                                from_cache = false;
                                last_owned_signal = std::time::Instant::now();
                            }
                            // desynced or corrupt response: drop the
                            // connection and reroute everything in flight
                            Ok(resp) => {
                                requeue(&mut in_flight);
                                if self.written_off(d, &mut attempts, from_cache, || {
                                    format!("{addr}: answered unknown job {}", resp.job)
                                }) {
                                    return;
                                }
                                continue 'reconnect;
                            }
                            Err(e) => {
                                requeue(&mut in_flight);
                                if self.written_off(d, &mut attempts, from_cache, || {
                                    format!("{addr}: bad response: {e}")
                                }) {
                                    return;
                                }
                                continue 'reconnect;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::HEARTBEAT, payload }) => {
                        // liveness beacon: the solve is progressing. Only a
                        // beat for a job we own proves *our* channel (a
                        // desynced peer echoing someone else's beat does
                        // not). A beat renews the silence clock and clears
                        // the stale-cache/busy suspicion, but deliberately
                        // NOT the reconnect-attempt budget — only a
                        // *delivered result* does that, so a worker that
                        // beats once and crashes on every connection still
                        // exhausts `max_attempts` instead of looping
                        // forever.
                        if let Ok(hb) = wire::decode_heartbeat(&payload) {
                            if in_flight.contains(&(hb.job as usize)) {
                                busy_since = None;
                                from_cache = false;
                                last_owned_signal = std::time::Instant::now();
                                if let Some(board) = &self.board {
                                    board.note_heartbeat(addr, &hb);
                                }
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::ERROR, payload }) => {
                        // an ERROR echoing one of OUR in-flight jobs is a
                        // deterministic solver failure: retrying on another
                        // worker would fail identically — abort the block.
                        // An ERROR for a job we don't own (the worker's
                        // u64::MAX protocol sentinel, or a desynced peer)
                        // is a transport fault: reroute and retry.
                        match wire::decode_error(&payload) {
                            Ok((job, m))
                                if usize::try_from(job)
                                    .map(|j| in_flight.contains(&j))
                                    .unwrap_or(false) =>
                            {
                                let msg = format!("worker {addr}, job {job}: {m}");
                                let mut fatal = lock(&d.fatal);
                                if fatal.is_none() {
                                    *fatal = Some(msg);
                                }
                                requeue(&mut in_flight);
                                return;
                            }
                            Ok((_, m)) => {
                                requeue(&mut in_flight);
                                if self.written_off(d, &mut attempts, from_cache, || {
                                    format!("{addr}: protocol error: {m}")
                                }) {
                                    return;
                                }
                                continue 'reconnect;
                            }
                            Err(e) => {
                                requeue(&mut in_flight);
                                lock(&d.worker_errors)
                                    .push(format!("{addr}: undecodable error: {e}"));
                                return;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::BUSY, .. }) => {
                        // worker at its connection cap: a healthy-but-full
                        // pool member, so it spends its own (much longer)
                        // patience budget, not the hard-failure attempts
                        requeue(&mut in_flight);
                        let since = *busy_since.get_or_insert_with(std::time::Instant::now);
                        if since.elapsed() >= self.cfg.busy_patience {
                            lock(&d.worker_errors).push(format!(
                                "{addr}: busy (at capacity) for {:.1}s",
                                since.elapsed().as_secs_f64()
                            ));
                            return;
                        }
                        std::thread::sleep(self.cfg.retry_backoff);
                        continue 'reconnect;
                    }
                    Ok(FrameRead::Frame { tag, .. }) => {
                        requeue(&mut in_flight);
                        lock(&d.worker_errors)
                            .push(format!("{addr}: unexpected frame tag {tag}"));
                        return;
                    }
                    Ok(FrameRead::Eof) | Ok(FrameRead::Shutdown) => {
                        // worker closed the connection mid-solve: reroute
                        requeue(&mut in_flight);
                        if self.written_off(d, &mut attempts, from_cache, || {
                            format!("{addr}: disconnected mid-solve")
                        }) {
                            return;
                        }
                        continue 'reconnect;
                    }
                    Err(e) => {
                        // keep the real cause: "no owned result/heartbeat
                        // for Ns" (missed-beat detection on a still-open
                        // connection) reads very differently from a
                        // dropped connection when debugging a pool
                        requeue(&mut in_flight);
                        if self.written_off(d, &mut attempts, from_cache, || {
                            format!("{addr}: {e}")
                        }) {
                            return;
                        }
                        continue 'reconnect;
                    }
                }
            }
        }
    }
}

impl Engine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded({})", self.spec.label())
    }

    fn config_digest(&self) -> String {
        // identical to NativeEngine's digest for the same spec, and the
        // worker list is deliberately excluded: neither the pool shape
        // nor remoting (nor where the gram is computed) changes a single
        // bit of the results, so checkpoints resume across pool changes
        // AND across the native/sharded boundary
        format!("{:?}", self.spec)
    }

    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult> {
        // borrowed straight through — no copy of the layer's matrices
        Ok(self.dispatch(&[problem], target)?.remove(0))
    }

    fn solve_block(
        &self,
        jobs: &[LayerJob],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        let problems: Vec<&LayerProblem> = jobs.iter().map(|j| &j.problem).collect();
        self.dispatch(&problems, target)
    }

    fn close(&self) {
        ShardedEngine::close(self)
    }
}

impl ShardedEngine {
    /// Drop every parked worker connection. Subsequent solves redial
    /// (reconnect-on-reuse), so `close` is safe at any point; the session
    /// calls it when a run finishes so worker slots free immediately
    /// instead of waiting for the engine to drop.
    pub fn close(&self) {
        for conn in &self.conns {
            lock(conn).take();
        }
    }

    /// Fan the borrowed problems across the pool; results are positional.
    fn dispatch(
        &self,
        problems: &[&LayerProblem],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        if problems.is_empty() {
            return Ok(Vec::new());
        }
        let d = Dispatch {
            problems,
            target,
            pending: Mutex::new((0..problems.len()).collect()),
            results: Mutex::new((0..problems.len()).map(|_| None).collect()),
            fatal: Mutex::new(None),
            worker_errors: Mutex::new(Vec::new()),
        };
        let d_ref = &d;
        std::thread::scope(|s| {
            for widx in 0..self.workers.len() {
                s.spawn(move || self.worker_loop(widx, d_ref));
            }
        });
        if let Some(msg) = lock(&d.fatal).take() {
            bail!("sharded solve failed: {msg}");
        }
        let results = d.results.into_inner().unwrap_or_else(|p| p.into_inner());
        let errors = d.worker_errors.into_inner().unwrap_or_else(|p| p.into_inner());
        let unsolved = results.iter().filter(|r| r.is_none()).count();
        if unsolved > 0 {
            bail!(
                "{unsolved} of {} layers unsolved — every worker failed: [{}]",
                problems.len(),
                errors.join("; ")
            );
        }
        if !errors.is_empty() {
            // the run completed, but part of the pool died along the way
            eprintln!("[sharded] degraded pool: {}", errors.join("; "));
        }
        // `unsolved == 0` above: every slot is Some, so flatten loses nothing
        Ok(results.into_iter().flatten().collect())
    }
}

/// Resolve `addr` and try **every** candidate address before giving up —
/// a dual-stack hostname that resolves IPv6-first must still reach a
/// worker listening on IPv4 (and vice versa) without burning a reconnect
/// attempt per address family.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    use std::net::ToSocketAddrs as _;
    let candidates: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address '{addr}'"))?
        .collect();
    connect_candidates(&candidates, timeout)
        .with_context(|| format!("connecting to worker {addr}"))
}

/// Dial the candidates in resolution order; first success wins, the last
/// failure is reported when none do.
fn connect_candidates(candidates: &[SocketAddr], timeout: Duration) -> Result<TcpStream> {
    if candidates.is_empty() {
        bail!("address resolved to nothing");
    }
    let mut last: Option<(SocketAddr, std::io::Error)> = None;
    for sa in candidates {
        match TcpStream::connect_timeout(sa, timeout) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                // short socket timeout: read_frame loops on ticks against
                // the heartbeat-grace / idle budgets
                stream.set_read_timeout(Some(Duration::from_millis(200)))?;
                stream.set_write_timeout(Some(Duration::from_secs(10)))?;
                return Ok(stream);
            }
            Err(e) => last = Some((*sa, e)),
        }
    }
    match last {
        Some((sa, e)) => {
            bail!("no candidate reachable ({} tried, last {sa}: {e})", candidates.len())
        }
        None => bail!("no candidate reachable (0 tried)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::worker::{Worker, WorkerConfig};
    use crate::pruning::NativeEngine;
    use std::net::TcpListener;

    fn jobs(n: usize, seed: u64) -> Vec<LayerJob> {
        (0..n)
            .map(|i| LayerJob {
                name: format!("blocks.0.l{i}"),
                problem: random_problem(14, 7, 50, seed + i as u64),
            })
            .collect()
    }

    fn quick_cfg() -> ShardedConfig {
        ShardedConfig {
            max_attempts: 2,
            connect_timeout: Duration::from_millis(500),
            idle_timeout: Duration::from_secs(30),
            heartbeat_grace: Duration::from_secs(30),
            retry_backoff: Duration::from_millis(10),
            busy_patience: Duration::from_millis(80),
            ..Default::default()
        }
    }

    fn spawn_worker() -> (String, std::sync::Arc<Worker>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
        let w = worker.clone();
        std::thread::spawn(move || {
            let _ = w.serve(listener);
        });
        (addr, worker)
    }

    #[test]
    fn sharded_block_matches_native_bitwise() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let spec = MethodSpec::Wanda;
            let js = jobs(5, 100);
            let target = SparsityTarget::Unstructured(0.6);
            let sharded =
                ShardedEngine::with_config(spec.clone(), vec![addr.clone()], quick_cfg())
                    .unwrap();
            let remote = sharded.solve_block(&js, target).unwrap();
            let local = NativeEngine::new(spec).solve_block(&js, target).unwrap();
            assert_eq!(remote.len(), local.len());
            for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
                assert_eq!(r.w, l.w, "job {i} differs from native");
                assert_eq!(r.worker.as_deref(), Some(addr.as_str()));
            }
            sharded.close();
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn shipped_activations_match_native_bitwise() {
        // --ship-activations path: X travels, the worker grams it. The
        // problems must be wide (rows < n_in) or the dispatcher would
        // rightly pick the smaller gram encoding instead.
        let (addr, worker) = spawn_worker();
        let spec = MethodSpec::SparseGpt(Default::default());
        let js: Vec<LayerJob> = (0..4)
            .map(|i| LayerJob {
                name: format!("blocks.0.wide{i}"),
                problem: random_problem(24, 8, 10, 500 + i as u64),
            })
            .collect();
        let target = SparsityTarget::Unstructured(0.55);
        let sharded = ShardedEngine::with_config(
            spec.clone(),
            vec![addr],
            ShardedConfig { ship_activations: true, ..quick_cfg() },
        )
        .unwrap();
        let remote = sharded.solve_block(&js, target).unwrap();
        let local = NativeEngine::new(spec).solve_block(&js, target).unwrap();
        for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
            assert_eq!(r.w, l.w, "job {i} differs with worker-side gram");
        }
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn connections_persist_across_block_solves_until_close() {
        let (addr, worker) = spawn_worker();
        let sharded = ShardedEngine::with_config(
            MethodSpec::Magnitude,
            vec![addr],
            quick_cfg(),
        )
        .unwrap();
        let target = SparsityTarget::Unstructured(0.5);
        // three "blocks" through one engine: one dial total
        for seed in [0u64, 10, 20] {
            sharded.solve_block(&jobs(3, seed), target).unwrap();
        }
        assert_eq!(
            worker.connections_accepted(),
            1,
            "persistent pool must reuse its connection across blocks"
        );
        // close() drops the parked connection; the next solve redials
        sharded.close();
        sharded.solve_block(&jobs(2, 30), target).unwrap();
        assert_eq!(worker.connections_accepted(), 2);
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn stale_parked_connection_gets_a_free_redial() {
        // a parked connection whose peer died between blocks must not
        // burn a retry attempt: with max_attempts=1 the solve still
        // succeeds because staleness redials for free
        let (addr, worker) = spawn_worker();
        let sharded = ShardedEngine::with_config(
            MethodSpec::Magnitude,
            vec![addr],
            ShardedConfig {
                max_attempts: 1,
                // if the dead peer never RSTs, the grace (not a hang)
                // converts its silence into the free redial
                heartbeat_grace: Duration::from_millis(300),
                ..quick_cfg()
            },
        )
        .unwrap();
        let target = SparsityTarget::Unstructured(0.5);
        sharded.solve_block(&jobs(2, 40), target).unwrap();
        // sabotage the parked connection: swap in a stream whose peer is
        // already gone (bound listener dropped after the connect)
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let peer = l.local_addr().unwrap();
            let s = TcpStream::connect(peer).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            s.set_write_timeout(Some(Duration::from_secs(1))).unwrap();
            drop(l);
            s
        };
        *lock(&sharded.conns[0]) = Some(dead);
        // would fail with max_attempts=1 if staleness cost an attempt
        sharded.solve_block(&jobs(2, 50), target).unwrap();
        sharded.close();
        worker.request_shutdown();
    }

    #[test]
    fn all_workers_dead_is_an_error_not_a_hang() {
        // bind then immediately drop: connection refused at that port
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![dead], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(2, 200), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("2 of 2 layers unsolved"), "{err}");
    }

    #[test]
    fn connect_tries_every_resolved_candidate() {
        // first candidate dead, second alive: the dial must fall through
        // to the live one instead of failing the attempt outright (the
        // dual-stack hostname case, pinned here with explicit addresses)
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let live = live_listener.local_addr().unwrap();
        let stream =
            connect_candidates(&[dead, live], Duration::from_millis(500)).unwrap();
        assert_eq!(stream.peer_addr().unwrap(), live);
        // no candidates / all dead errors mention the count
        assert!(connect_candidates(&[], Duration::from_millis(100)).is_err());
        let err = connect_candidates(&[dead], Duration::from_millis(100))
            .unwrap_err()
            .to_string();
        assert!(err.contains("1 tried"), "{err}");
    }

    #[test]
    fn solver_error_aborts_instead_of_retrying() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            // structured ALPS rejects N:M targets deterministically
            let sharded = ShardedEngine::with_config(
                MethodSpec::AlpsStructured(Default::default()),
                vec![addr],
                quick_cfg(),
            )
            .unwrap();
            let err = sharded
                .solve_block(&jobs(2, 300), SparsityTarget::NM { n: 2, m: 4 })
                .unwrap_err()
                .to_string();
            assert!(err.contains("sharded solve failed"), "{err}");
            assert!(err.contains("N:M"), "{err}");
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn busy_worker_is_retryable_not_fatal() {
        // a BUSY refusal must never abort the run the way a solver error
        // does — it exhausts its own patience budget (not the hard-failure
        // attempts) and the worker is written off, not the block failed
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let fake = std::thread::spawn(move || {
            // a permanently-saturated worker: BUSY on every connection
            listener.set_nonblocking(true).unwrap();
            while !done2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        let _ = conn.set_nonblocking(false);
                        let _ = write_frame(
                            &mut conn,
                            tag::BUSY,
                            &wire::encode_error(0, "worker connection limit reached (1)"),
                        );
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![addr], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(1, 400), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unsolved"), "not fatal, just written off: {err}");
        assert!(err.contains("busy"), "{err}");
        done.store(true, Ordering::SeqCst);
        fake.join().unwrap();
    }

    #[test]
    fn empty_workers_rejected_and_flag_parses() {
        assert!(ShardedEngine::new(MethodSpec::Wanda, vec![]).is_err());
        let e = ShardedEngine::from_flag(MethodSpec::Wanda, "a:1, b:2,,").unwrap();
        let got: Vec<&str> = e.workers().iter().map(String::as_str).collect();
        assert_eq!(got, vec!["a:1", "b:2"]);
        assert_eq!(e.label(), "sharded(wanda)");
        assert!(ShardedEngine::from_flag(MethodSpec::Wanda, " ,").is_err());
    }
}
