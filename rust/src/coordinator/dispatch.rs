//! Coordinator-side dispatcher for distributed pruning: a
//! [`ShardedEngine`] implementing [`crate::pruning::Engine`] that ships
//! [`LayerProblem`]s to a pool of `alps worker` processes over the binary
//! frame protocol ([`crate::pruning::wire`]) and reassembles results
//! deterministically.
//!
//! Design:
//!
//! * **One dispatcher thread per worker**, all draining one shared job
//!   queue — a fast worker naturally takes more layers (work stealing by
//!   construction), and layer order never matters because results land in
//!   a slot indexed by job position.
//! * **Per-worker outstanding-request limit**
//!   ([`ShardedConfig::max_outstanding`]): each connection pipelines a
//!   bounded number of in-flight solves, enough to hide the round trip
//!   without buffering a whole block on one worker.
//! * **Retry on disconnect**: a failed connect, a broken connection, or a
//!   hung worker ([`ShardedConfig::idle_timeout`]) requeues that worker's
//!   in-flight jobs at the *front* of the queue (another worker picks
//!   them up next) and the worker gets a bounded number of reconnect
//!   attempts ([`ShardedConfig::max_attempts`]). The run completes as
//!   long as one worker survives; only when every pool member is gone do
//!   unsolved layers fail the block.
//! * **Solver errors are not retried**: a worker answering `tag::ERROR`
//!   for a job this connection owns hit a deterministic failure (bad
//!   target for the method, degenerate problem) that would fail
//!   identically anywhere, so the whole block aborts with that message.
//!   Transport-level refusals (`tag::BUSY` at the connection cap, or an
//!   ERROR carrying the worker's protocol sentinel instead of an owned
//!   job id) stay retryable.
//! * **Bit-identical results**: matrices travel bit-exactly
//!   (`to_le_bytes` round-trip), the worker rebuilds the problem with the
//!   same deterministic kernels, and reassembly is positional — a sharded
//!   run equals a [`NativeEngine`] run to the last bit (proven by
//!   `tests/integration_sharded.rs` and the CI smoke step).

use crate::config::SparsityTarget;
use crate::net::framing::{read_frame, write_frame, FrameRead};
use crate::net::lock;
use crate::pruning::engine::{Engine, LayerJob, LayerResult};
use crate::pruning::wire::{self, tag};
use crate::pruning::{LayerProblem, MethodSpec};
use anyhow::{bail, Context as _, Result};
use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

/// Dispatcher tuning knobs.
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    /// Pipelined in-flight solves per worker connection.
    pub max_outstanding: usize,
    /// Connect/reconnect attempts per worker before it is written off.
    pub max_attempts: usize,
    /// Largest accepted response frame.
    pub max_frame_bytes: usize,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// A worker sending nothing for this long counts as hung and its
    /// in-flight jobs are rerouted. Generous: a big ALPS layer solve can
    /// legitimately take minutes.
    pub idle_timeout: Duration,
    /// Pause between reconnect attempts.
    pub retry_backoff: Duration,
    /// How long to keep retrying a worker that answers BUSY (at its
    /// connection cap) before writing it off. Separate from
    /// `max_attempts`: a saturated worker is healthy and a slot may free
    /// at any moment, so it gets far more patience than a broken one.
    pub busy_patience: Duration,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            max_outstanding: 2,
            max_attempts: 3,
            max_frame_bytes: 1 << 30,
            connect_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(600),
            retry_backoff: Duration::from_millis(100),
            busy_patience: Duration::from_secs(60),
        }
    }
}

/// Poll interval while a drained-queue worker waits for possible
/// reroutes: a job is only truly gone once its result slot is filled, so
/// survivors linger until the whole block is solved (or failed).
const WAIT_POLL: Duration = Duration::from_millis(50);

/// Shared dispatch state for one block solve. Holds borrowed problems —
/// the dispatcher never copies a layer's matrices except into the wire
/// encoding itself.
struct Dispatch<'j> {
    problems: &'j [&'j LayerProblem],
    target: SparsityTarget,
    /// Job indices not yet assigned (rerouted jobs return to the front).
    pending: Mutex<VecDeque<usize>>,
    /// One slot per job, positional — deterministic reassembly.
    results: Mutex<Vec<Option<LayerResult>>>,
    /// First deterministic solver error; aborts the block.
    fatal: Mutex<Option<String>>,
    /// Transport-level failure per written-off worker (diagnostics).
    worker_errors: Mutex<Vec<String>>,
}

impl Dispatch<'_> {
    fn all_solved(&self) -> bool {
        !lock(&self.results).iter().any(|r| r.is_none())
    }
}

/// A pruning [`Engine`] that fans layer solves across remote workers.
pub struct ShardedEngine {
    spec: MethodSpec,
    workers: Vec<String>,
    cfg: ShardedConfig,
}

impl ShardedEngine {
    /// `workers` are `host:port` addresses of running `alps worker`
    /// processes (at least one).
    pub fn new(spec: MethodSpec, workers: Vec<String>) -> Result<ShardedEngine> {
        Self::with_config(spec, workers, ShardedConfig::default())
    }

    pub fn with_config(
        spec: MethodSpec,
        workers: Vec<String>,
        cfg: ShardedConfig,
    ) -> Result<ShardedEngine> {
        if workers.is_empty() {
            bail!("ShardedEngine needs at least one worker address");
        }
        let cfg = ShardedConfig {
            max_outstanding: cfg.max_outstanding.max(1),
            max_attempts: cfg.max_attempts.max(1),
            ..cfg
        };
        Ok(ShardedEngine { spec, workers, cfg })
    }

    /// Parse a CLI `host:port,host:port` list.
    pub fn from_flag(spec: MethodSpec, flag: &str) -> Result<ShardedEngine> {
        let workers: Vec<String> = flag
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        Self::new(spec, workers)
    }

    pub fn workers(&self) -> &[String] {
        &self.workers
    }

    /// One worker's dispatch loop: connect, keep up to `max_outstanding`
    /// solves in flight, reroute on failure.
    fn worker_loop(&self, addr: &str, d: &Dispatch) {
        let mut attempts = 0usize;
        // set at the first BUSY answer; cleared by any successful solve
        let mut busy_since: Option<std::time::Instant> = None;
        'reconnect: loop {
            if lock(&d.fatal).is_some() || d.all_solved() {
                return;
            }
            if lock(&d.pending).is_empty() {
                // unsolved layers are in flight on other workers; linger in
                // case one dies and reroutes them here
                std::thread::sleep(WAIT_POLL);
                continue 'reconnect;
            }
            let stream = match connect(addr, self.cfg.connect_timeout) {
                Ok(s) => s,
                Err(e) => {
                    attempts += 1;
                    if attempts >= self.cfg.max_attempts {
                        lock(&d.worker_errors).push(format!("{addr}: {e}"));
                        return;
                    }
                    std::thread::sleep(self.cfg.retry_backoff);
                    continue 'reconnect;
                }
            };
            let mut reader = match stream.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    lock(&d.worker_errors).push(format!("{addr}: clone failed: {e}"));
                    return;
                }
            };
            let mut writer = stream;
            // in-flight job indices, in send order
            let mut in_flight: VecDeque<usize> = VecDeque::new();
            // cleared when a pipelined send stalls: a busy worker only
            // reads between solves, so a huge second frame can exceed the
            // socket buffer and the write timeout without anything being
            // wrong — stop sending, keep reading (the write may have been
            // partial, so the channel can't carry further requests), and
            // replace the connection once the in-flight drain completes
            let mut can_send = true;
            let requeue = |in_flight: &mut VecDeque<usize>| {
                let mut pending = lock(&d.pending);
                // front of the queue: a surviving worker reroutes these
                // before taking fresh work
                while let Some(idx) = in_flight.pop_back() {
                    pending.push_front(idx);
                }
            };
            loop {
                if lock(&d.fatal).is_some() {
                    requeue(&mut in_flight);
                    return;
                }
                // top up the pipeline
                while can_send && in_flight.len() < self.cfg.max_outstanding {
                    let Some(idx) = lock(&d.pending).pop_front() else { break };
                    let problem = d.problems[idx];
                    // borrow-encode: no deep copy of the (possibly huge)
                    // weight and gram matrices just to serialize them
                    let payload = wire::encode_solve(
                        idx as u64,
                        d.target,
                        &self.spec,
                        &problem.what,
                        &problem.h,
                    );
                    if let Err(e) = write_frame(&mut writer, tag::SOLVE, &payload) {
                        lock(&d.pending).push_front(idx);
                        if in_flight.is_empty() {
                            // a saturated worker may have refused us with a
                            // BUSY still sitting in our receive buffer (its
                            // refusal drain is bounded, so a huge frame can
                            // fail the write first) — prefer that
                            // classification over a hard failure
                            let refusal = read_frame(
                                &mut reader,
                                self.cfg.max_frame_bytes,
                                None,
                                Some(Duration::from_secs(1)),
                            );
                            if let Ok(FrameRead::Frame { tag: tag::BUSY, .. }) = refusal {
                                let since = *busy_since
                                    .get_or_insert_with(std::time::Instant::now);
                                if since.elapsed() >= self.cfg.busy_patience {
                                    lock(&d.worker_errors).push(format!(
                                        "{addr}: busy (at capacity) for {:.1}s",
                                        since.elapsed().as_secs_f64()
                                    ));
                                    return;
                                }
                                std::thread::sleep(self.cfg.retry_backoff);
                                continue 'reconnect;
                            }
                            // nothing owed on this connection: a failed
                            // write really is a broken worker link
                            attempts += 1;
                            if attempts >= self.cfg.max_attempts {
                                lock(&d.worker_errors)
                                    .push(format!("{addr}: send failed: {e}"));
                                return;
                            }
                            std::thread::sleep(self.cfg.retry_backoff);
                            continue 'reconnect;
                        }
                        // backpressure, not failure: the worker is solving
                        // and not reading — drain its responses instead
                        can_send = false;
                        break;
                    }
                    in_flight.push_back(idx);
                }
                if in_flight.is_empty() {
                    if !can_send {
                        // write side poisoned (possibly partial frame) but
                        // fully drained: replace the connection; attempts
                        // was reset by the drained responses
                        continue 'reconnect;
                    }
                    // queue drained and nothing owed to us — but jobs in
                    // flight on *other* workers may still reroute here, so
                    // only leave once every result slot is filled
                    if d.all_solved() || lock(&d.fatal).is_some() {
                        return;
                    }
                    if lock(&d.pending).is_empty() {
                        std::thread::sleep(WAIT_POLL);
                    }
                    continue;
                }
                match read_frame(
                    &mut reader,
                    self.cfg.max_frame_bytes,
                    None,
                    Some(self.cfg.idle_timeout),
                ) {
                    Ok(FrameRead::Frame { tag: tag::RESULT, payload }) => {
                        match wire::SolveResponse::decode(&payload) {
                            Ok(resp) if in_flight.contains(&(resp.job as usize)) => {
                                let idx = resp.job as usize;
                                in_flight.retain(|&i| i != idx);
                                lock(&d.results)[idx] = Some(LayerResult {
                                    w: resp.w,
                                    secs: resp.secs,
                                    admm_iters: resp.admm_iters as usize,
                                    worker: Some(addr.to_string()),
                                });
                                // a delivered solve proves the worker
                                // healthy; give transient failures a fresh
                                // retry budget
                                attempts = 0;
                                busy_since = None;
                            }
                            // desynced or corrupt response: drop the
                            // connection and reroute everything in flight
                            Ok(resp) => {
                                requeue(&mut in_flight);
                                attempts += 1;
                                if attempts >= self.cfg.max_attempts {
                                    lock(&d.worker_errors).push(format!(
                                        "{addr}: answered unknown job {}",
                                        resp.job
                                    ));
                                    return;
                                }
                                continue 'reconnect;
                            }
                            Err(e) => {
                                requeue(&mut in_flight);
                                attempts += 1;
                                if attempts >= self.cfg.max_attempts {
                                    lock(&d.worker_errors)
                                        .push(format!("{addr}: bad response: {e}"));
                                    return;
                                }
                                continue 'reconnect;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::ERROR, payload }) => {
                        // an ERROR echoing one of OUR in-flight jobs is a
                        // deterministic solver failure: retrying on another
                        // worker would fail identically — abort the block.
                        // An ERROR for a job we don't own (the worker's
                        // u64::MAX protocol sentinel, or a desynced peer)
                        // is a transport fault: reroute and retry.
                        match wire::decode_error(&payload) {
                            Ok((job, m))
                                if usize::try_from(job)
                                    .map(|j| in_flight.contains(&j))
                                    .unwrap_or(false) =>
                            {
                                let msg = format!("worker {addr}, job {job}: {m}");
                                let mut fatal = lock(&d.fatal);
                                if fatal.is_none() {
                                    *fatal = Some(msg);
                                }
                                requeue(&mut in_flight);
                                return;
                            }
                            Ok((_, m)) => {
                                requeue(&mut in_flight);
                                attempts += 1;
                                if attempts >= self.cfg.max_attempts {
                                    lock(&d.worker_errors)
                                        .push(format!("{addr}: protocol error: {m}"));
                                    return;
                                }
                                std::thread::sleep(self.cfg.retry_backoff);
                                continue 'reconnect;
                            }
                            Err(e) => {
                                requeue(&mut in_flight);
                                lock(&d.worker_errors)
                                    .push(format!("{addr}: undecodable error: {e}"));
                                return;
                            }
                        }
                    }
                    Ok(FrameRead::Frame { tag: tag::BUSY, .. }) => {
                        // worker at its connection cap: a healthy-but-full
                        // pool member, so it spends its own (much longer)
                        // patience budget, not the hard-failure attempts
                        requeue(&mut in_flight);
                        let since = *busy_since.get_or_insert_with(std::time::Instant::now);
                        if since.elapsed() >= self.cfg.busy_patience {
                            lock(&d.worker_errors).push(format!(
                                "{addr}: busy (at capacity) for {:.1}s",
                                since.elapsed().as_secs_f64()
                            ));
                            return;
                        }
                        std::thread::sleep(self.cfg.retry_backoff);
                        continue 'reconnect;
                    }
                    Ok(FrameRead::Frame { tag, .. }) => {
                        requeue(&mut in_flight);
                        lock(&d.worker_errors)
                            .push(format!("{addr}: unexpected frame tag {tag}"));
                        return;
                    }
                    Ok(FrameRead::Eof) | Ok(FrameRead::Shutdown) | Err(_) => {
                        // worker dropped mid-solve: reroute its jobs
                        requeue(&mut in_flight);
                        attempts += 1;
                        if attempts >= self.cfg.max_attempts {
                            lock(&d.worker_errors)
                                .push(format!("{addr}: disconnected mid-solve"));
                            return;
                        }
                        std::thread::sleep(self.cfg.retry_backoff);
                        continue 'reconnect;
                    }
                }
            }
        }
    }
}

impl Engine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded({})", self.spec.label())
    }

    fn config_digest(&self) -> String {
        // identical to NativeEngine's digest for the same spec, and the
        // worker list is deliberately excluded: neither the pool shape
        // nor remoting changes a single bit of the results, so
        // checkpoints resume across pool changes AND across the
        // native/sharded boundary
        format!("{:?}", self.spec)
    }

    fn solve_layer(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<LayerResult> {
        // borrowed straight through — no copy of the layer's matrices
        Ok(self.dispatch(&[problem], target)?.remove(0))
    }

    fn solve_block(
        &self,
        jobs: &[LayerJob],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        let problems: Vec<&LayerProblem> = jobs.iter().map(|j| &j.problem).collect();
        self.dispatch(&problems, target)
    }
}

impl ShardedEngine {
    /// Fan the borrowed problems across the pool; results are positional.
    fn dispatch(
        &self,
        problems: &[&LayerProblem],
        target: SparsityTarget,
    ) -> Result<Vec<LayerResult>> {
        if problems.is_empty() {
            return Ok(Vec::new());
        }
        let d = Dispatch {
            problems,
            target,
            pending: Mutex::new((0..problems.len()).collect()),
            results: Mutex::new((0..problems.len()).map(|_| None).collect()),
            fatal: Mutex::new(None),
            worker_errors: Mutex::new(Vec::new()),
        };
        let d_ref = &d;
        std::thread::scope(|s| {
            for addr in &self.workers {
                // `move` copies the three references; `addr` itself is a
                // per-iteration binding the thread must not borrow
                s.spawn(move || self.worker_loop(addr, d_ref));
            }
        });
        if let Some(msg) = lock(&d.fatal).take() {
            bail!("sharded solve failed: {msg}");
        }
        let results = d.results.into_inner().unwrap_or_else(|p| p.into_inner());
        let errors = d.worker_errors.into_inner().unwrap_or_else(|p| p.into_inner());
        let unsolved = results.iter().filter(|r| r.is_none()).count();
        if unsolved > 0 {
            bail!(
                "{unsolved} of {} layers unsolved — every worker failed: [{}]",
                problems.len(),
                errors.join("; ")
            );
        }
        if !errors.is_empty() {
            // the run completed, but part of the pool died along the way
            eprintln!("[sharded] degraded pool: {}", errors.join("; "));
        }
        Ok(results.into_iter().map(|r| r.expect("checked above")).collect())
    }
}

/// `TcpStream::connect_timeout` needs a resolved `SocketAddr`; resolve
/// through `ToSocketAddrs` first (hostnames allowed).
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    use std::net::ToSocketAddrs as _;
    let resolved = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address '{addr}'"))?
        .next()
        .with_context(|| format!("worker address '{addr}' resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&resolved, timeout)
        .with_context(|| format!("connecting to worker {addr}"))?;
    let _ = stream.set_nodelay(true);
    // short socket timeout: read_frame loops on ticks against idle_timeout
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    Ok(stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_problem;
    use crate::pruning::worker::{Worker, WorkerConfig};
    use crate::pruning::NativeEngine;
    use std::net::TcpListener;

    fn jobs(n: usize, seed: u64) -> Vec<LayerJob> {
        (0..n)
            .map(|i| LayerJob {
                name: format!("blocks.0.l{i}"),
                problem: random_problem(14, 7, 50, seed + i as u64),
            })
            .collect()
    }

    fn quick_cfg() -> ShardedConfig {
        ShardedConfig {
            max_attempts: 2,
            connect_timeout: Duration::from_millis(500),
            idle_timeout: Duration::from_secs(30),
            retry_backoff: Duration::from_millis(10),
            busy_patience: Duration::from_millis(80),
            ..Default::default()
        }
    }

    #[test]
    fn sharded_block_matches_native_bitwise() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            let spec = MethodSpec::Wanda;
            let js = jobs(5, 100);
            let target = SparsityTarget::Unstructured(0.6);
            let sharded =
                ShardedEngine::with_config(spec.clone(), vec![addr.clone()], quick_cfg())
                    .unwrap();
            let remote = sharded.solve_block(&js, target).unwrap();
            let local = NativeEngine::new(spec).solve_block(&js, target).unwrap();
            assert_eq!(remote.len(), local.len());
            for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
                assert_eq!(r.w, l.w, "job {i} differs from native");
                assert_eq!(r.worker.as_deref(), Some(addr.as_str()));
            }
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn all_workers_dead_is_an_error_not_a_hang() {
        // bind then immediately drop: connection refused at that port
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![dead], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(2, 200), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("2 of 2 layers unsolved"), "{err}");
    }

    #[test]
    fn solver_error_aborts_instead_of_retrying() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Worker::new(WorkerConfig::default());
        std::thread::scope(|s| {
            let srv = s.spawn(|| worker.serve(listener));
            // structured ALPS rejects N:M targets deterministically
            let sharded = ShardedEngine::with_config(
                MethodSpec::AlpsStructured(Default::default()),
                vec![addr],
                quick_cfg(),
            )
            .unwrap();
            let err = sharded
                .solve_block(&jobs(2, 300), SparsityTarget::NM { n: 2, m: 4 })
                .unwrap_err()
                .to_string();
            assert!(err.contains("sharded solve failed"), "{err}");
            assert!(err.contains("N:M"), "{err}");
            worker.request_shutdown();
            srv.join().unwrap().unwrap();
        });
    }

    #[test]
    fn busy_worker_is_retryable_not_fatal() {
        // a BUSY refusal must never abort the run the way a solver error
        // does — it exhausts its own patience budget (not the hard-failure
        // attempts) and the worker is written off, not the block failed
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let fake = std::thread::spawn(move || {
            // a permanently-saturated worker: BUSY on every connection
            listener.set_nonblocking(true).unwrap();
            while !done2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        let _ = conn.set_nonblocking(false);
                        let _ = write_frame(
                            &mut conn,
                            tag::BUSY,
                            &wire::encode_error(0, "worker connection limit reached (1)"),
                        );
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        let sharded =
            ShardedEngine::with_config(MethodSpec::Magnitude, vec![addr], quick_cfg())
                .unwrap();
        let err = sharded
            .solve_block(&jobs(1, 400), SparsityTarget::Unstructured(0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unsolved"), "not fatal, just written off: {err}");
        assert!(err.contains("busy"), "{err}");
        done.store(true, Ordering::SeqCst);
        fake.join().unwrap();
    }

    #[test]
    fn empty_workers_rejected_and_flag_parses() {
        assert!(ShardedEngine::new(MethodSpec::Wanda, vec![]).is_err());
        let e = ShardedEngine::from_flag(MethodSpec::Wanda, "a:1, b:2,,").unwrap();
        let got: Vec<&str> = e.workers().iter().map(String::as_str).collect();
        assert_eq!(got, vec!["a:1", "b:2"]);
        assert_eq!(e.label(), "sharded(wanda)");
        assert!(ShardedEngine::from_flag(MethodSpec::Wanda, " ,").is_err());
    }
}
