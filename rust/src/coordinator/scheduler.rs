//! Deprecated compatibility layer over the session API.
//!
//! The block-by-block pipeline, the thread-pool fan-out, and the engine
//! dispatch moved to [`crate::pruning::session::PruneSession`] and
//! [`crate::pruning::engine::Engine`]. This module keeps the previous
//! entry points — [`Scheduler::prune_model`] driven by the [`PruneEngine`]
//! enum — alive as thin shims for one release so downstream callers can
//! migrate at their own pace. New code should use `PruneSession::builder()`
//! with a typed [`MethodSpec`] or an explicit engine.

use super::report::RunReport;
use crate::config::{AlpsConfig, SparsityTarget};
use crate::model::Model;
use crate::pruning::engine::HloEngine;
use crate::pruning::{MethodSpec, PruneSession};
use crate::runtime::Runtime;
use anyhow::Result;

// The single-layer helpers live with the session now; re-exported here so
// `coordinator::scheduler::single_layer_problem` keeps resolving.
pub use crate::pruning::session::{direct_rel_error, single_layer_problem};

/// Which engine executes the per-layer optimization.
#[deprecated(
    note = "use pruning::MethodSpec with PruneSession::builder().method(..) \
            or .engine(Box::new(HloEngine::new(..)))"
)]
pub enum PruneEngine<'rt> {
    /// Pure-rust implementation of the named method.
    Native(String),
    /// ALPS via the AOT HLO artifacts (falls back to native for shapes
    /// without artifacts).
    Hlo(&'rt Runtime, AlpsConfig),
}

/// The sequential block-by-block pruning pipeline (deprecated shim).
pub struct Scheduler {
    /// Calibration sequences (token ids, each seq_len long).
    pub calib: Vec<Vec<u16>>,
    /// Print progress lines.
    pub verbose: bool,
}

impl Scheduler {
    pub fn new(calib: Vec<Vec<u16>>) -> Self {
        Scheduler { calib, verbose: false }
    }

    /// Prune `model` in place to `target` using `engine`.
    ///
    /// Behavior note vs the pre-session implementation: the method name
    /// is normalized through [`MethodSpec::parse`], so `RunReport.method`
    /// carries the canonical label (`"magnitude"` reports as `"mp"`; all
    /// other accepted names are already canonical).
    #[deprecated(note = "use PruneSession::builder().calib(..).target(..).run(model)")]
    #[allow(deprecated)]
    pub fn prune_model(
        &self,
        model: &mut Model,
        target: SparsityTarget,
        engine: &PruneEngine,
    ) -> Result<RunReport> {
        let builder = PruneSession::builder()
            .calib(self.calib.clone())
            .target(target)
            .verbose(self.verbose);
        match engine {
            PruneEngine::Native(name) => {
                builder.method(MethodSpec::parse(name)?).run(model)
            }
            PruneEngine::Hlo(rt, cfg) => {
                builder.engine(Box::new(HloEngine::new(rt, cfg.clone()))).run(model)
            }
        }
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;
    use crate::util::Rng;

    fn calib_seqs(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
            .collect()
    }

    #[test]
    fn deprecated_shim_matches_session() {
        // the shim must produce exactly what the session produces
        let calib = calib_seqs(4, 8, 24, 1);
        let target = SparsityTarget::Unstructured(0.5);

        let mut m_shim = random_model(0);
        let sched = Scheduler::new(calib.clone());
        let report = sched
            .prune_model(&mut m_shim, target, &PruneEngine::Native("wanda".into()))
            .unwrap();
        assert_eq!(report.layers.len(), 2 * 6);
        assert_eq!(report.method, "wanda");

        let mut m_sess = random_model(0);
        PruneSession::builder()
            .calib(calib)
            .target(target)
            .method(MethodSpec::Wanda)
            .run(&mut m_sess)
            .unwrap();
        for (name, t_shim) in &m_shim.weights.tensors {
            assert_eq!(
                t_shim.data,
                m_sess.weights.tensors.get(name).unwrap().data,
                "tensor '{name}' differs between shim and session"
            );
        }
    }

    #[test]
    fn shim_rejects_unknown_method() {
        let mut model = random_model(2);
        let sched = Scheduler::new(calib_seqs(2, 8, 24, 3));
        let err = sched
            .prune_model(
                &mut model,
                SparsityTarget::Unstructured(0.5),
                &PruneEngine::Native("???".into()),
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown method"), "{err}");
    }
}
