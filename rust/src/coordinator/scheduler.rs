//! Layer-wise pruning scheduler with activation propagation and gram
//! caching; native methods fan the per-tap work across a thread pool, the
//! PJRT path stays on the coordinator thread (PJRT handles are !Send).

use super::report::{LayerReport, RunReport};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::linalg::matmul::{gram, matmul};
use crate::linalg::Matrix;
use crate::model::{prunable_layers, ActivationTap, Model};
use crate::pruning::{method_by_name, LayerProblem};
use crate::runtime::executor::AlpsHlo;
use crate::runtime::Runtime;
use crate::util::Timer;
use anyhow::Result;
use std::collections::HashMap;

/// Which engine executes the per-layer optimization.
pub enum PruneEngine<'rt> {
    /// Pure-rust implementation of the named method.
    Native(String),
    /// ALPS via the AOT HLO artifacts (falls back to native for shapes
    /// without artifacts).
    Hlo(&'rt Runtime, AlpsConfig),
}

/// The sequential block-by-block pruning pipeline.
pub struct Scheduler {
    /// Calibration sequences (token ids, each seq_len long).
    pub calib: Vec<Vec<u16>>,
    /// Print progress lines.
    pub verbose: bool,
}

impl Scheduler {
    pub fn new(calib: Vec<Vec<u16>>) -> Self {
        Scheduler { calib, verbose: false }
    }

    /// Prune `model` in place to `target` using `engine`.
    pub fn prune_model(
        &self,
        model: &mut Model,
        target: SparsityTarget,
        engine: &PruneEngine,
    ) -> Result<RunReport> {
        let total_timer = Timer::start();
        let mut report = RunReport {
            method: match engine {
                PruneEngine::Native(name) => name.clone(),
                PruneEngine::Hlo(..) => "alps(hlo)".into(),
            },
            target: target.label(),
            model: model.cfg.name.clone(),
            ..Default::default()
        };

        for block in 0..model.cfg.n_layers {
            // (1) capture this block's layer inputs under current weights
            let inputs = model.forward_collect(&self.calib, block)?;

            // (2) gram per activation tap (wq/wk/wv share AttnIn)
            let mut grams: HashMap<ActivationTap, Matrix> = HashMap::new();
            for (tap, x) in &inputs.taps {
                grams.insert(*tap, gram(x));
            }

            // (3) prune the six matrices
            let layers = prunable_layers(block);
            let mut results: Vec<(String, Matrix, LayerReport)> = Vec::new();
            match engine {
                PruneEngine::Native(name) => {
                    // native methods are Send-free of PJRT: parallelize
                    // across matrices with scoped threads
                    let jobs: Vec<(String, ActivationTap)> = layers;
                    let problems: Vec<(String, LayerProblem)> = jobs
                        .iter()
                        .map(|(lname, tap)| {
                            let h = grams[tap].clone();
                            let what = model.weights.matrix(lname)?;
                            Ok((lname.clone(), LayerProblem::from_gram(h, what)?))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    let outs = std::thread::scope(|s| {
                        let handles: Vec<_> = problems
                            .iter()
                            .map(|(lname, p)| {
                                let method_name = name.clone();
                                s.spawn(move || -> Result<(String, Matrix, f64, usize)> {
                                    let t = Timer::start();
                                    let method = method_by_name(&method_name)?;
                                    let w = method.prune(p, target)?;
                                    Ok((lname.clone(), w, t.elapsed_secs(), 0))
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("prune worker panicked"))
                            .collect::<Result<Vec<_>>>()
                    })?;
                    for ((lname, p), (lname2, w, secs, iters)) in
                        problems.iter().zip(outs)
                    {
                        debug_assert_eq!(lname, &lname2);
                        results.push((
                            lname.clone(),
                            w.clone(),
                            LayerReport {
                                name: lname.clone(),
                                n_in: p.n_in(),
                                n_out: p.n_out(),
                                kept: w.nnz(),
                                total: p.n_in() * p.n_out(),
                                rel_error: p.rel_error(&w),
                                secs,
                                admm_iters: iters,
                            },
                        ));
                    }
                }
                PruneEngine::Hlo(rt, cfg) => {
                    for (lname, tap) in &layers {
                        let t = Timer::start();
                        let h = grams[tap].clone();
                        let what = model.weights.matrix(lname)?;
                        let p = LayerProblem::from_gram(h, what)?;
                        let hlo = AlpsHlo { rt, cfg: cfg.clone() };
                        let (w, trace) = if hlo.supports(p.n_in(), p.n_out(), target) {
                            hlo.prune_traced(&p, target)?
                        } else {
                            crate::pruning::alps::Alps::with_config(cfg.clone())
                                .prune_traced(&p, target)?
                        };
                        results.push((
                            lname.clone(),
                            w.clone(),
                            LayerReport {
                                name: lname.clone(),
                                n_in: p.n_in(),
                                n_out: p.n_out(),
                                kept: w.nnz(),
                                total: p.n_in() * p.n_out(),
                                rel_error: p.rel_error(&w),
                                secs: t.elapsed_secs(),
                                admm_iters: trace.admm_iters,
                            },
                        ));
                    }
                }
            }

            // (4) write back
            for (lname, w, rep) in results {
                model.weights.set_matrix(&lname, &w)?;
                if self.verbose {
                    println!(
                        "  [{}] {} {}x{} kept={} err={:.4} ({:.2}s)",
                        block, rep.name, rep.n_in, rep.n_out, rep.kept,
                        rep.rel_error, rep.secs
                    );
                }
                report.layers.push(rep);
            }
        }
        report.total_secs = total_timer.elapsed_secs();
        Ok(report)
    }
}

/// Build a single-layer problem from a model layer + calibration data
/// (used by the Fig.2 / Table 1 single-layer experiments).
pub fn single_layer_problem(
    model: &Model,
    calib: &[Vec<u16>],
    block: usize,
    layer: &str,
) -> Result<LayerProblem> {
    let inputs = model.forward_collect(calib, block)?;
    let tap = prunable_layers(block)
        .into_iter()
        .find(|(n, _)| n.ends_with(layer))
        .map(|(_, t)| t)
        .ok_or_else(|| anyhow::anyhow!("no layer '{layer}' in block {block}"))?;
    let x = &inputs.taps[&tap];
    let h = gram(x);
    let what = model.weights.matrix(&format!("blocks.{block}.{layer}"))?;
    LayerProblem::from_gram(h, what)
}

/// Dense output of a layer on its calibration inputs — used by tests to
/// cross-check the gram-based error against the direct definition.
pub fn direct_rel_error(x: &Matrix, what: &Matrix, w: &Matrix) -> f64 {
    let dense = matmul(x, what);
    let pruned = matmul(x, w);
    let diff = dense.sub(&pruned);
    diff.fro_norm_sq() / dense.fro_norm_sq().max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::testutil::random_model;
    use crate::util::Rng;

    fn calib_seqs(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
            .collect()
    }

    #[test]
    fn prunes_whole_model_native() {
        let mut model = random_model(0);
        let calib = calib_seqs(4, 8, 24, 1);
        let sched = Scheduler::new(calib);
        let target = SparsityTarget::Unstructured(0.5);
        let report = sched
            .prune_model(&mut model, target, &PruneEngine::Native("mp".into()))
            .unwrap();
        assert_eq!(report.layers.len(), 2 * 6);
        let s = report.overall_sparsity();
        assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
        // weights actually written back
        let names = model.prunable_names();
        assert!(model.weights.sparsity_of(&names) > 0.45);
    }

    #[test]
    fn alps_native_beats_mp_through_pipeline() {
        let calib = calib_seqs(4, 8, 24, 2);
        let target = SparsityTarget::Unstructured(0.7);
        let mut m_alps = random_model(3);
        let mut m_mp = random_model(3);
        let sched = Scheduler::new(calib);
        let r_alps = sched
            .prune_model(&mut m_alps, target, &PruneEngine::Native("alps".into()))
            .unwrap();
        let r_mp = sched
            .prune_model(&mut m_mp, target, &PruneEngine::Native("mp".into()))
            .unwrap();
        assert!(
            r_alps.mean_rel_error() < r_mp.mean_rel_error(),
            "alps {} !< mp {}",
            r_alps.mean_rel_error(),
            r_mp.mean_rel_error()
        );
    }

    #[test]
    fn single_layer_problem_builds() {
        let model = random_model(4);
        let calib = calib_seqs(3, 8, 24, 5);
        let p = single_layer_problem(&model, &calib, 0, "attn.wq").unwrap();
        assert_eq!(p.n_in(), 16);
        assert_eq!(p.n_out(), 16);
        assert!(single_layer_problem(&model, &calib, 0, "nope").is_err());
    }

    #[test]
    fn gram_error_matches_direct_error() {
        let model = random_model(5);
        let calib = calib_seqs(3, 8, 24, 6);
        let inputs = model.forward_collect(&calib, 0).unwrap();
        let x = &inputs.taps[&ActivationTap::AttnIn];
        let what = model.weights.matrix("blocks.0.attn.wq").unwrap();
        let p = LayerProblem::from_activations(x, &what).unwrap();
        let w = crate::pruning::projection::topk_project(&what, 100);
        let e1 = p.rel_error(&w);
        let e2 = direct_rel_error(x, &what, &w);
        assert!((e1 - e2).abs() < 1e-3, "{e1} vs {e2}");
    }
}
