//! Run reports: per-layer and whole-run pruning records.

/// One pruned matrix.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub n_in: usize,
    pub n_out: usize,
    pub kept: usize,
    pub total: usize,
    /// Relative reconstruction error on this layer's calibration inputs.
    pub rel_error: f64,
    /// Seconds spent pruning this matrix.
    pub secs: f64,
    /// ADMM iterations (ALPS only, 0 otherwise).
    pub admm_iters: usize,
}

impl LayerReport {
    pub fn sparsity(&self) -> f64 {
        1.0 - self.kept as f64 / self.total.max(1) as f64
    }
}

/// Whole-run record.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub method: String,
    pub target: String,
    pub model: String,
    pub layers: Vec<LayerReport>,
    pub total_secs: f64,
}

impl RunReport {
    pub fn overall_sparsity(&self) -> f64 {
        let kept: usize = self.layers.iter().map(|l| l.kept).sum();
        let total: usize = self.layers.iter().map(|l| l.total).sum();
        1.0 - kept as f64 / total.max(1) as f64
    }

    pub fn mean_rel_error(&self) -> f64 {
        if self.layers.is_empty() {
            return f64::NAN;
        }
        self.layers.iter().map(|l| l.rel_error).sum::<f64>() / self.layers.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} {} on {}: {} layers, sparsity {:.3}, mean layer rel-err {:.4}, {:.1}s",
            self.method,
            self.target,
            self.model,
            self.layers.len(),
            self.overall_sparsity(),
            self.mean_rel_error(),
            self.total_secs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(kept: usize, total: usize, err: f64) -> LayerReport {
        LayerReport {
            name: "l".into(),
            n_in: 4,
            n_out: 4,
            kept,
            total,
            rel_error: err,
            secs: 0.1,
            admm_iters: 10,
        }
    }

    #[test]
    fn sparsity_math() {
        assert!((layer(30, 100, 0.0).sparsity() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn run_aggregates() {
        let mut r = RunReport {
            method: "alps".into(),
            target: "0.70".into(),
            model: "alps-tiny".into(),
            ..Default::default()
        };
        r.layers.push(layer(30, 100, 0.1));
        r.layers.push(layer(10, 100, 0.3));
        assert!((r.overall_sparsity() - 0.8).abs() < 1e-12);
        assert!((r.mean_rel_error() - 0.2).abs() < 1e-12);
        assert!(r.summary().contains("alps"));
    }
}
