//! Coordinator-level records and compatibility shims for the layer-wise
//! pruning pipeline.
//!
//! The pipeline itself — the paper's sequential block-by-block loop
//! (Appendix B.1), the gram cache, the engine dispatch, streaming
//! progress, and checkpoint/resume — lives in
//! [`crate::pruning::session::PruneSession`]; the solve backends
//! (native thread-pool fan-out, AOT HLO artifacts) implement
//! [`crate::pruning::engine::Engine`]. What remains here:
//!
//! * [`report`] — the per-layer / whole-run records every engine and
//!   session produces ([`LayerReport`], [`RunReport`]).
//! * [`dispatch`] — the distributed coordinator: [`ShardedEngine`] keeps
//!   a long-lived owned-job pool whose dispatcher threads outlive any
//!   single block, fanning layer solves across a **dynamic** fleet of
//!   `alps worker` endpoints over TCP. Jobs are `Arc`'d self-contained
//!   units on a shared queue; workers join mid-run through the REGISTER
//!   handshake ([`ShardedEngine::listen_for_registrations`]) and leave
//!   (crash, silence, refused redials) by having their owned jobs
//!   requeued. Persistent per-worker connections are reused across
//!   blocks, dead workers are detected by missed heartbeats, per-worker
//!   outstanding-request limits bound buffering, heartbeat-derived
//!   solve-time estimates steer small layers toward slow members, and
//!   optional activation shipping moves gram computation worker-side.
//!   It plugs into the session through the same
//!   [`crate::pruning::Engine`] trait as the local backends — with
//!   bit-identical results even under mid-run membership churn — and
//!   reports per-worker RPC latency, retries, reroutes, wire bytes, and
//!   the fleet lifecycle into the process-global [`crate::obs`] registry
//!   (`alps_coord_*` series).
//! * [`scheduler`] — the deprecated [`Scheduler`] + [`PruneEngine`] shims
//!   (one release of backwards compatibility) plus re-exports of the
//!   single-layer experiment helpers.
//!
//! Typical modern usage:
//!
//! ```no_run
//! use alps::config::SparsityTarget;
//! use alps::pruning::{MethodSpec, PruneSession};
//! # fn demo(model: &mut alps::model::Model, calib: Vec<Vec<u16>>) -> anyhow::Result<()> {
//! let report = PruneSession::builder()
//!     .calib(calib)
//!     .target(SparsityTarget::parse("0.7")?)
//!     .method(MethodSpec::parse("alps")?)
//!     .run(model)?;
//! println!("{}", report.summary());
//! # Ok(()) }
//! ```

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod dispatch;
pub mod report;
pub mod scheduler;

pub use dispatch::{ShardedConfig, ShardedEngine};
pub use report::{LayerReport, RunReport};
#[allow(deprecated)]
pub use scheduler::PruneEngine;
pub use scheduler::Scheduler;
