//! The layer-wise pruning coordinator: the paper's sequential pipeline
//! (Appendix B.1 — "solve the LLM pruning problem sequentially, layer by
//! layer; the input activation matrix X is the output of the previous
//! pruned layers on the calibration samples").
//!
//! For each transformer block, the coordinator (1) re-runs the partially
//! pruned model over the calibration set to capture the block's layer
//! inputs, (2) builds one gram matrix per activation tap (wq/wk/wv share
//! one — the gram cache), (3) prunes the six matrices, and (4) writes the
//! sparse weights back before moving to the next block.

pub mod report;
pub mod scheduler;

pub use report::{LayerReport, RunReport};
pub use scheduler::{PruneEngine, Scheduler};
