//! Evaluation: perplexity (HF full-stride convention) and zero-shot
//! accuracy by length-normalized log-likelihood.

use crate::data::tasks::Task;
use crate::data::eval_windows;
use crate::model::Model;
use anyhow::Result;

/// Perplexity of a model over a token stream, full stride: exp(mean NLL)
/// over non-overlapping seq_len windows.
pub fn perplexity(model: &Model, ids: &[u16]) -> Result<f64> {
    perplexity_windows(model, &eval_windows(ids, model.cfg.seq_len))
}

/// Perplexity over explicit windows (shared by the native and artifact
/// eval paths).
pub fn perplexity_windows(model: &Model, windows: &[Vec<u16>]) -> Result<f64> {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for w in windows {
        let nll = model.nll(w)?;
        total += nll.iter().sum::<f64>();
        count += nll.len();
    }
    if count == 0 {
        anyhow::bail!("no eval windows");
    }
    Ok((total / count as f64).exp())
}

/// Perplexity computed from precomputed per-window NLL sums (artifact path).
pub fn perplexity_from_nll(total_nll: f64, n_positions: usize) -> f64 {
    (total_nll / n_positions.max(1) as f64).exp()
}

/// Zero-shot accuracy on one task: pick the continuation with the highest
/// length-normalized log-likelihood given the prefix.
pub fn zero_shot_accuracy(model: &Model, task: &Task) -> Result<f64> {
    let mut correct = 0usize;
    for item in &task.items {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in item.choices.iter().enumerate() {
            let mut ids = item.prefix.clone();
            ids.extend_from_slice(choice);
            let nll = model.nll(&ids)?;
            // score only the continuation positions
            let cont = &nll[nll.len() - choice.len()..];
            let ll = -cont.iter().sum::<f64>() / choice.len() as f64;
            if ll > best.0 {
                best = (ll, ci);
            }
        }
        if best.1 == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.items.len().max(1) as f64)
}

/// A (metric name, value) result row.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub metric: String,
    pub value: f64,
    /// Higher is better (accuracy) vs lower is better (perplexity).
    pub higher_better: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::lambada_like;
    use crate::model::transformer::testutil::random_model;

    fn stream(n: usize) -> Vec<u16> {
        (0..n).map(|i| ((i * 3 + 1) % 24) as u16).collect()
    }

    #[test]
    fn perplexity_near_vocab_for_random_model() {
        let m = random_model(0);
        let ppl = perplexity(&m, &stream(120)).unwrap();
        // untrained model ~ uniform: ppl within a factor of vocab size
        assert!(ppl > 3.0 && ppl < 120.0, "ppl {ppl}");
    }

    #[test]
    fn perplexity_errors_on_empty() {
        let m = random_model(1);
        assert!(perplexity(&m, &stream(5)).is_err()); // < seq_len
    }

    #[test]
    fn zero_shot_random_model_near_chance() {
        let m = random_model(2);
        let task = lambada_like(&stream(600), 40, 10, 24, 0);
        let acc = zero_shot_accuracy(&m, &task).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        // chance is 0.25; random model should not be (near-)perfect
        assert!(acc < 0.8, "acc {acc}");
    }

    #[test]
    fn perplexity_from_nll_math() {
        assert!((perplexity_from_nll(0.0, 10) - 1.0).abs() < 1e-12);
        assert!((perplexity_from_nll(10.0 * (2.0f64).ln(), 10) - 2.0).abs() < 1e-9);
    }
}
