//! ALPS — ADMM-based one-shot LLM pruning (NeurIPS 2024 reproduction),
//! plus the serving stack that cashes in the sparsity.
//!
//! Three-layer architecture (see DESIGN.md):
//! * Layer 3 (this crate): coordinator — config, data pipeline, the
//!   [`pruning::PruneSession`] pipeline (typed [`pruning::MethodSpec`]s,
//!   pluggable [`pruning::Engine`] backends, streaming progress,
//!   checkpoint/resume), all pruning methods, transformer inference, eval.
//! * Layer 2: JAX graphs AOT-compiled to `artifacts/*.hlo.txt`.
//! * Layer 1: Pallas kernels inside those graphs.
//!
//! The `runtime` module executes the AOT artifacts via PJRT; every pruning
//! method also has a pure-rust native path used for tests and baselines.
//!
//! On top of pruning, the crate serves generation traffic from the pruned
//! weights:
//! * `model` — dense transformer forward plus the incremental KV-cache
//!   decode path ([`model::Decoder`]): per-token cost is O(context)
//!   attention + O(1) weight matmuls instead of a full prefix re-forward.
//!   The [`model::DecodeOps`] seam runs the same decode over dense
//!   matrices, the CSR [`model::SparseModel`], or the packed N:M
//!   [`sparse::NmModel`].
//! * `serve` — continuous-batching generation engine (engine / batcher /
//!   tcp / metrics) behind the `alps serve` CLI subcommand: batched
//!   multi-row prompt prefill at admission and a threaded
//!   multi-connection TCP front-end; `bench_serve` load-tests it
//!   dense-vs-sparse across sparsity levels. See `serve/mod.rs` for the
//!   architecture and wire protocol.
//! * `linalg` — dense blocked/threaded matmul (thread count overridable
//!   via `ALPS_THREADS`) and u32-indexed CSR kernels.
//! * `sparse` — the packed semi-structured N:M format
//!   ([`sparse::NmPacked`]: contiguous values, bit-packed in-group
//!   indices, no indptr) and the [`sparse::NmModel`] decode backend
//!   behind `alps serve --format nm`; bit-identical to the CSR path,
//!   with per-layer CSR fallback for mixed checkpoints.
//! * `net` — the shared TCP transport layer (bounded line reads,
//!   length-prefixed binary frames, threaded accept loop with connection
//!   cap and graceful shutdown drain) under both the serve front-end and
//!   the distributed pruning endpoints.
//! * `obs` — the unified observability layer: a process-global metrics
//!   registry (lock-free counters/gauges/histograms) plus tracing spans
//!   with an optional `--trace-out` JSONL sink, exported as Prometheus
//!   text on `GET /metrics` by every TCP endpoint (serve front-end,
//!   `alps worker`, `--status-addr`).
//!
//! Pruning scales out horizontally: `alps worker` hosts the native
//! solvers behind a binary frame protocol (`pruning::worker` +
//! `pruning::wire`, protocol v2: gram-or-activations calibration
//! payloads and keepalive heartbeats while solving),
//! `coordinator::ShardedEngine` fans a block's layer problems across a
//! persistent worker pool (connections reused across blocks) with
//! heartbeat-based dead-worker detection, retry, and deterministic
//! reassembly (bit-identical to a local run), and `pruning::status`
//! serves live `ProgressEvent` snapshots over TCP.

// CI runs `cargo clippy -- -D warnings`; the numeric kernels throughout
// this crate deliberately use explicit index loops (they mirror the math
// and the Pallas kernels), so keep that one style lint out of the gate.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod lint;
pub mod model;
pub mod net;
pub mod obs;
pub mod pruning;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod util;
