//! ALPS — ADMM-based one-shot LLM pruning (NeurIPS 2024 reproduction).
//!
//! Three-layer architecture (see DESIGN.md):
//! * Layer 3 (this crate): coordinator — config, data pipeline, layer-wise
//!   pruning scheduler, all pruning methods, transformer inference, eval.
//! * Layer 2: JAX graphs AOT-compiled to `artifacts/*.hlo.txt`.
//! * Layer 1: Pallas kernels inside those graphs.
//!
//! The `runtime` module executes the AOT artifacts via PJRT; every pruning
//! method also has a pure-rust native path used for tests and baselines.
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod pruning;
pub mod runtime;
pub mod util;
