//! High-level executors over the AOT artifacts: the ALPS hot path (ADMM
//! iterations + PCG refinement as single HLO calls per step) and the
//! model-forward evaluator.
//!
//! The math here is identical to `pruning::alps` (native path); the
//! integration tests pin the two against each other. What moves to the
//! device: the two ridge-solve matmuls, the top-k projection (sort +
//! runtime-k threshold), the dual update, and the entire 10-iteration PCG
//! loop (one HLO while-loop, zero host round-trips inside).

use super::artifact::Manifest;
use super::client::{Runtime, Value};
use crate::config::{AlpsConfig, SparsityTarget};
use crate::linalg::{Matrix, SymEig};
use crate::model::Model;
use crate::pruning::alps::{rho_update, AlpsTrace, DiagScaling};
use crate::pruning::LayerProblem;
use anyhow::{bail, Result};

/// ALPS executed through the AOT artifacts.
pub struct AlpsHlo<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: AlpsConfig,
}

impl<'rt> AlpsHlo<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        AlpsHlo { rt, cfg: AlpsConfig::default() }
    }

    /// Does the runtime have artifacts for this layer shape + target?
    pub fn supports(&self, n_in: usize, n_out: usize, target: SparsityTarget) -> bool {
        let iter_name = match target {
            SparsityTarget::Unstructured(_) => Manifest::admm_iter_name(n_in, n_out),
            SparsityTarget::NM { n, m } => Manifest::admm_iter_nm_name(n_in, n_out, n, m),
        };
        self.rt.has(&iter_name) && self.rt.has(&Manifest::pcg_refine_name(n_in, n_out))
    }

    /// Run ALPS on a layer problem via the artifacts.
    pub fn prune_traced(
        &self,
        problem: &LayerProblem,
        target: SparsityTarget,
    ) -> Result<(Matrix, AlpsTrace)> {
        let cfg = &self.cfg;
        let n_in = problem.n_in();
        let n_out = problem.n_out();
        let k = target.keep_count(n_in, n_out);
        let iter_name = match target {
            SparsityTarget::Unstructured(_) => Manifest::admm_iter_name(n_in, n_out),
            SparsityTarget::NM { n, m } => Manifest::admm_iter_nm_name(n_in, n_out, n, m),
        };
        if !self.rt.has(&iter_name) {
            bail!("no artifact '{iter_name}' for shape {n_in}x{n_out}");
        }

        // host-side prep: B.1 scaling + eigendecomposition (once per layer)
        let (scaling, hs) = DiagScaling::from_gram(&problem.h, cfg.damp);
        let gs = scaling.scale_g(&problem.g);
        let whats = scaling.to_scaled(&problem.what);
        let eig = SymEig::new(&hs)?;

        // §Perf: constants (Q, m_eig, G, k) are uploaded to the device once
        // per layer; only D, V (and rho when it changes) move per iteration.
        let q_buf = self.rt.upload_f32(&eig.q.data, &[n_in, n_in])?;
        let m_buf = self.rt.upload_f32(&eig.vals, &[n_in])?;
        let g_buf = self.rt.upload_f32(&gs.data, &[n_in, n_out])?;
        let k_buf = self.rt.upload_i32(&[k as i32], &[])?;
        let unstructured = matches!(target, SparsityTarget::Unstructured(_));

        let mut d = whats.clone();
        let mut v = Matrix::zeros(n_in, n_out);
        let mut rho = cfg.rho0;
        let mut rho_buf = self.rt.upload_f32(&[rho], &[])?;
        let mut t = 0usize;
        let mut trace = AlpsTrace {
            admm_iters: 0,
            final_rho: rho,
            support_changes: Vec::new(),
            primal_gaps: Vec::new(),
            pcg_iters: 0,
        };
        // the artifact reports |supp(D_new) Δ supp(D_old)| per iteration;
        // accumulate over each update_every window for the rho scheme.
        while t < cfg.max_iters {
            let mut window_delta = 0usize;
            let mut last_gap = 0.0f64;
            for _ in 0..cfg.update_every {
                let d_buf = self.rt.upload_f32(&d.data, &[n_in, n_out])?;
                let v_buf = self.rt.upload_f32(&v.data, &[n_in, n_out])?;
                let mut args: Vec<&xla::PjRtBuffer> =
                    vec![&q_buf, &m_buf, &g_buf, &d_buf, &v_buf, &rho_buf];
                if unstructured {
                    args.push(&k_buf);
                }
                let out = self.rt.execute_buffers(&iter_name, &args)?;
                let [w_o, d_o, v_o, delta_o, _nnz_o]: [Vec<f32>; 5] =
                    out.try_into().map_err(|_| anyhow::anyhow!("bad output arity"))?;
                let w = Matrix::from_vec(n_in, n_out, w_o);
                d = Matrix::from_vec(n_in, n_out, d_o);
                v = Matrix::from_vec(n_in, n_out, v_o);
                window_delta = delta_o[0] as usize;
                last_gap = w.sub(&d).fro_norm() as f64;
                t += 1;
            }
            trace.support_changes.push(window_delta);
            trace.primal_gaps.push(last_gap);
            if window_delta == 0 {
                break;
            }
            let new_rho = rho_update(rho, window_delta, k, cfg);
            if new_rho != rho {
                rho = new_rho;
                rho_buf = self.rt.upload_f32(&[rho], &[])?;
            }
        }
        trace.admm_iters = t;
        trace.final_rho = rho;

        // PCG refinement: one artifact call (10 iterations inside HLO)
        let mask = d.support_mask();
        let pcg_name = Manifest::pcg_refine_name(n_in, n_out);
        let out = self.rt.run(
            &pcg_name,
            &[
                Value::matrix(&hs),
                Value::matrix(&gs),
                Value::matrix(&d),
                Value::matrix(&mask),
            ],
        )?;
        let w_refined = out[0].clone().into_matrix(n_in, n_out)?;
        trace.pcg_iters = 10;
        Ok((scaling.to_unscaled(&w_refined), trace))
    }
}

/// Compute (H, G) on the device when a gram artifact matches the shape;
/// falls back to the native gram otherwise.
pub fn gram_via_runtime(
    rt: &Runtime,
    x: &Matrix,
    what: &Matrix,
) -> Result<(Matrix, Matrix)> {
    let name = Manifest::gram_name(x.rows, x.cols, what.cols);
    if rt.has(&name) {
        let out = rt.run(&name, &[Value::matrix(x), Value::matrix(what)])?;
        let h = out[0].clone().into_matrix(x.cols, x.cols)?;
        let g = out[1].clone().into_matrix(x.cols, what.cols)?;
        Ok((h, g))
    } else {
        let h = crate::linalg::matmul::gram(x);
        let g = crate::linalg::matmul::matmul(&h, what);
        Ok((h, g))
    }
}

/// Model-forward evaluator over the `model_fwd_{name}` artifact:
/// batch of token ids -> per-position NLL.
pub struct ModelFwdHlo<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    batch: usize,
    seq_len: usize,
    /// Flattened weights in param_spec order (converted once).
    weight_values: Vec<Value>,
}

impl<'rt> ModelFwdHlo<'rt> {
    pub fn new(rt: &'rt Runtime, model: &Model) -> Result<Self> {
        let artifact = Manifest::model_fwd_name(&model.cfg.name);
        let spec = rt.manifest.get(&artifact)?;
        // inputs: ids, then weights in order
        let ids_spec = &spec.inputs[0];
        if ids_spec.shape.len() != 2 {
            bail!("model_fwd ids input must be 2-D");
        }
        let (batch, seq_len) = (ids_spec.shape[0], ids_spec.shape[1]);
        let mut weight_values = Vec::new();
        for io in &spec.inputs[1..] {
            let t = model.weights.get(&io.name)?;
            if t.numel() != io.numel() {
                bail!(
                    "weight '{}' numel {} != artifact {}",
                    io.name,
                    t.numel(),
                    io.numel()
                );
            }
            weight_values.push(Value::F32(t.data.clone(), io.shape.clone()));
        }
        Ok(ModelFwdHlo { rt, artifact, batch, seq_len, weight_values })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Refresh one weight tensor after pruning (cheap: no recompilation).
    pub fn update_weight(&mut self, model: &Model, name: &str) -> Result<()> {
        let spec = self.rt.manifest.get(&self.artifact)?;
        for (i, io) in spec.inputs[1..].iter().enumerate() {
            if io.name == name {
                let t = model.weights.get(name)?;
                self.weight_values[i] = Value::F32(t.data.clone(), io.shape.clone());
                return Ok(());
            }
        }
        bail!("weight '{name}' not an input of {}", self.artifact)
    }

    /// Per-position NLL for a batch of sequences (each exactly seq_len
    /// long; the batch is padded by repeating the last sequence and the
    /// padding rows are discarded).
    pub fn nll_batch(&self, seqs: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let mut out = Vec::with_capacity(seqs.len());
        for chunk in seqs.chunks(self.batch) {
            let mut ids = Vec::with_capacity(self.batch * self.seq_len);
            for i in 0..self.batch {
                let s = chunk.get(i).unwrap_or_else(|| chunk.last().unwrap());
                if s.len() != self.seq_len {
                    bail!("sequence length {} != artifact seq_len {}", s.len(), self.seq_len);
                }
                ids.extend(s.iter().map(|&x| x as f32));
            }
            // ids input is i32 in the artifact: Value::F32 would mismatch.
            // Build a dedicated literal path: encode as i32 via Value::I32?
            // The runtime Value enum supports i32 scalars only, so we pass
            // through a raw execution instead.
            let nll = self.run_raw(&ids, chunk.len())?;
            out.extend(nll);
        }
        Ok(out)
    }

    fn run_raw(&self, ids_f32: &[f32], n_valid: usize) -> Result<Vec<Vec<f64>>> {
        // Execute with a hand-built literal list: i32 ids + f32 weights.
        let ids_i32: Vec<i32> = ids_f32.iter().map(|&x| x as i32).collect();
        let spec = self.rt.manifest.get(&self.artifact)?.clone();
        let mut values = Vec::with_capacity(1 + self.weight_values.len());
        values.push(RawInput::I32Tensor(ids_i32, vec![self.batch, self.seq_len]));
        for v in &self.weight_values {
            match v {
                Value::F32(d, s) => values.push(RawInput::F32Tensor(d.clone(), s.clone())),
                Value::I32(_) => bail!("unexpected scalar weight"),
            }
        }
        let out = self.rt.run_raw(&self.artifact, &values)?;
        let nll_flat = &out[0];
        let per = self.seq_len - 1;
        if nll_flat.len() != self.batch * per {
            bail!("nll output len {} != {}", nll_flat.len(), self.batch * per);
        }
        let _ = spec;
        Ok((0..n_valid)
            .map(|b| nll_flat[b * per..(b + 1) * per].iter().map(|&x| x as f64).collect())
            .collect())
    }
}

/// Raw (dtype-explicit) input for executions that mix i32 tensors.
pub enum RawInput {
    F32Tensor(Vec<f32>, Vec<usize>),
    I32Tensor(Vec<i32>, Vec<usize>),
}

impl Runtime {
    /// Execute with explicit raw inputs (used by the model-forward path
    /// whose ids input is an i32 *tensor*, which `Value` doesn't model).
    pub fn run_raw(&self, name: &str, inputs: &[RawInput]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        for inp in inputs {
            match inp {
                RawInput::F32Tensor(d, shape) => {
                    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                    lits.push(xla::Literal::vec1(d).reshape(&dims)?);
                }
                RawInput::I32Tensor(d, shape) => {
                    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                    lits.push(xla::Literal::vec1(d).reshape(&dims)?);
                }
            }
        }
        self.execute_lits(name, &lits)
    }
}

#[cfg(test)]
mod tests {
    // exercised by rust/tests/integration_runtime.rs (requires artifacts)
}
