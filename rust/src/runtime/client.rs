//! PJRT client wrapper with a compiled-executable cache: each artifact is
//! compiled once per process and reused across every layer/iteration.

use super::artifact::{ArtifactSpec, Dtype, Manifest};
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// A runtime value passed to / returned from an artifact.
#[derive(Clone, Debug)]
pub enum Value {
    /// f32 tensor with shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 scalar.
    I32(i32),
}

impl Value {
    pub fn matrix(m: &Matrix) -> Value {
        Value::F32(m.data.clone(), vec![m.rows, m.cols])
    }

    pub fn vector(v: &[f32]) -> Value {
        Value::F32(v.to_vec(), vec![v.len()])
    }

    pub fn scalar(x: f32) -> Value {
        Value::F32(vec![x], vec![])
    }

    pub fn numel(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(_) => 1,
        }
    }

    pub fn into_matrix(self, rows: usize, cols: usize) -> Result<Matrix> {
        match self {
            Value::F32(d, _) => {
                if d.len() != rows * cols {
                    bail!("value has {} elems, expected {rows}x{cols}", d.len());
                }
                Ok(Matrix::from_vec(rows, cols, d))
            }
            Value::I32(_) => bail!("expected f32 tensor"),
        }
    }

    pub fn into_vec(self) -> Result<Vec<f32>> {
        match self {
            Value::F32(d, _) => Ok(d),
            Value::I32(_) => bail!("expected f32 tensor"),
        }
    }
}

/// PJRT runtime with executable cache. Not Sync — PJRT handles are raw
/// pointers; the coordinator keeps runtime work on one thread.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// executions per artifact (perf accounting)
    pub exec_counts: RefCell<HashMap<String, usize>>,
}

impl Runtime {
    /// Create a runtime over the given artifacts directory.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    pub fn with_default_dir() -> Result<Runtime> {
        Runtime::new(&super::artifact::default_dir())
    }

    /// True if the manifest declares this artifact.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Compile (and cache) an artifact if not already compiled.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        self.compile(name)
    }

    /// Execute a compiled artifact with prepared literals; returns the raw
    /// f32 data of each tuple output.
    pub fn execute_lits(&self, name: &str, lits: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.compile(name)?;
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(lits)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>()?);
        }
        Ok(out)
    }

    fn compile(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.path_of(name)?;
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact by name with typed inputs; returns one Value per
    /// declared output. Inputs are validated against the manifest.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec: ArtifactSpec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' takes {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            let ok = match (v, io.dtype) {
                (Value::F32(d, _), Dtype::F32) => d.len() == io.numel(),
                (Value::I32(_), Dtype::I32) => true,
                _ => false,
            };
            if !ok {
                bail!(
                    "artifact '{name}' input '{}' expects {:?} {:?}, got {} elems",
                    io.name,
                    io.dtype,
                    io.shape,
                    v.numel()
                );
            }
        }
        self.compile(name)?;
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;

        let mut lits: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            match v {
                Value::F32(d, _) => {
                    let l = xla::Literal::vec1(d);
                    let dims: Vec<i64> = io.shape.iter().map(|&x| x as i64).collect();
                    lits.push(if dims.is_empty() {
                        // scalar: reshape to rank-0
                        l.reshape(&[])?
                    } else {
                        l.reshape(&dims)?
                    });
                }
                Value::I32(x) => lits.push(xla::Literal::from(*x)),
            }
        }
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                tuple.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, io) in tuple.into_iter().zip(&spec.outputs) {
            out.push(Value::F32(lit.to_vec::<f32>()?, io.shape.clone()));
        }
        Ok(out)
    }

    /// Total artifact executions so far (all names).
    pub fn total_execs(&self) -> usize {
        self.exec_counts.borrow().values().sum()
    }

    /// Upload an f32 tensor to the device (§Perf: constants like Q / m_eig
    /// / G are uploaded once per layer instead of once per iteration).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Upload an i32 tensor/scalar to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    /// Execute with device-resident input buffers (zero host->device copies
    /// for the arguments); returns the raw f32 data per tuple output.
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>> {
        self.compile(name)?;
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled above");
        let result = exe.execute_b::<&xla::PjRtBuffer>(args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>()?);
        }
        Ok(out)
    }
}
