//! Artifact manifest: the typed index of every AOT-exported HLO program
//! (written by `python/compile/aot.py` as `artifacts/manifest.json`).

use crate::config::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Declared dtype of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One declared input or output.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Parsed manifest + artifact directory.
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_io(v: &Json, default_dtype: Dtype) -> Result<IoSpec> {
    let name = v.get("name")?.as_str()?.to_string();
    let shape = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|s| s.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let dtype = match v.get("dtype") {
        Ok(d) => match d.as_str()? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype '{other}'"),
        },
        Err(_) => default_dtype,
    };
    Ok(IoSpec { name, shape, dtype })
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        for ent in v.as_arr()? {
            let spec = ArtifactSpec {
                name: ent.get("name")?.as_str()?.to_string(),
                file: ent.get("file")?.as_str()?.to_string(),
                kind: ent.get("kind")?.as_str()?.to_string(),
                inputs: ent
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(|io| parse_io(io, Dtype::F32))
                    .collect::<Result<Vec<_>>>()?,
                outputs: ent
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|io| parse_io(io, Dtype::F32))
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!("artifact '{name}' not in manifest (run `make artifacts`?)")
        })
    }

    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Artifact name for one ADMM iteration at the given shape.
    pub fn admm_iter_name(n_in: usize, n_out: usize) -> String {
        format!("admm_iter_{n_in}x{n_out}")
    }

    pub fn admm_iter_nm_name(n_in: usize, n_out: usize, n: usize, m: usize) -> String {
        format!("admm_iter_nm{n}of{m}_{n_in}x{n_out}")
    }

    pub fn pcg_refine_name(n_in: usize, n_out: usize) -> String {
        format!("pcg_refine_{n_in}x{n_out}")
    }

    pub fn gram_name(rows: usize, n_in: usize, n_out: usize) -> String {
        format!("gram_{rows}x{n_in}_{n_out}")
    }

    pub fn model_fwd_name(model: &str) -> String {
        format!("model_fwd_{model}")
    }
}

/// Default artifacts directory: $ALPS_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("ALPS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
  {
    "name": "admm_iter_16x8",
    "file": "admm_iter_16x8.hlo.txt",
    "kind": "admm_iter",
    "inputs": [{"name": "q", "shape": [16,16], "dtype": "f32"},
               {"name": "k", "shape": [], "dtype": "i32"}],
    "outputs": [{"name": "w", "shape": [16,8], "dtype": "f32"}]
  }
]"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("alps_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let spec = m.get("admm_iter_16x8").unwrap();
        assert_eq!(spec.kind, "admm_iter");
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[1].dtype, Dtype::I32);
        assert_eq!(spec.inputs[0].numel(), 256);
        assert_eq!(spec.outputs[0].shape, vec![16, 8]);
        assert!(m.get("nope").is_err());
        assert!(m.path_of("admm_iter_16x8").unwrap().ends_with("admm_iter_16x8.hlo.txt"));
    }

    #[test]
    fn name_helpers() {
        assert_eq!(Manifest::admm_iter_name(128, 512), "admm_iter_128x512");
        assert_eq!(Manifest::admm_iter_nm_name(256, 256, 2, 4), "admm_iter_nm2of4_256x256");
        assert_eq!(Manifest::pcg_refine_name(1024, 256), "pcg_refine_1024x256");
        assert_eq!(Manifest::gram_name(4096, 256, 1024), "gram_4096x256_1024");
        assert_eq!(Manifest::model_fwd_name("alps-base"), "model_fwd_alps-base");
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let dir = std::env::temp_dir().join("alps_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        let err = match Manifest::load(&dir) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
