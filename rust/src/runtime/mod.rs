//! PJRT runtime: load the AOT HLO-text artifacts (`artifacts/*.hlo.txt`)
//! and execute them on the CPU PJRT client. Python never runs here — the
//! binary is self-contained once `make artifacts` has produced the files.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::Manifest;
pub use client::Runtime;

/// Back-compat smoke helper used by `alps smoke` (see main.rs).
pub mod smoke {
    use anyhow::Result;

    /// Load an HLO text artifact and run it with the given f32 inputs.
    pub fn run_hlo_f32(
        path: &str,
        inputs: &[(Vec<f32>, Vec<i64>)],
        scalar_i32: Option<i32>,
    ) -> Result<Vec<Vec<f32>>> {
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let mut lits: Vec<xla::Literal> = Vec::new();
        for (data, shape) in inputs {
            lits.push(xla::Literal::vec1(data).reshape(shape)?);
        }
        if let Some(k) = scalar_i32 {
            lits.push(xla::Literal::from(k));
        }
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::new();
        for t in tuple {
            out.push(t.to_vec::<f32>()?);
        }
        Ok(out)
    }
}
