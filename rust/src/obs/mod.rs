//! `obs` — the unified observability layer: one process-global metrics
//! registry, a Prometheus text encoder, and lightweight tracing spans.
//!
//! Before this module each subsystem watched itself its own way
//! (`serve/metrics.rs` sliding windows, the `pruning/status.rs` JSON
//! snapshot, bench-only timers). `obs` gives them one substrate so a
//! single scraper covers a whole fleet — a sharded 70%-sparsity pruning
//! run and a serving replica show up in the same Prometheus instance.
//!
//! * [`registry`] — atomic counters, gauges, and fixed-bucket histograms
//!   behind cloneable `Arc` handles. Registration (name + pre-declared
//!   label set) takes a lock once; recording through a handle is
//!   lock-free and allocation-free, so decode steps and ADMM inner loops
//!   can record without perturbing what they measure. [`global()`]
//!   returns the process-wide registry every endpoint renders.
//! * [`prometheus`] — text exposition (format 0.0.4): `# HELP`/`# TYPE`
//!   blocks, escaped labels, cumulative `_bucket{le=...}` histograms.
//!   Served as `GET /metrics` by all three TCP endpoints — the serve
//!   front-end (next to `/healthz`), `alps worker`, and the `prune
//!   --status-addr` server.
//! * [`trace`] — spans (monotonic start + duration + key=value fields)
//!   and point events, written as JSONL to an optional `--trace-out`
//!   sink for offline analysis; a no-op behind one atomic load otherwise.
//!
//! ## Metric naming
//!
//! `alps_<subsystem>_<name>`, with base units (seconds, bytes) and
//! `_total` on counters. The table below is the authoritative set:
//! `alps-lint` (rule 4, `cargo run --bin alps_lint`) fails the build
//! when a registration uses a name missing from this table, when a name
//! violates its module's subsystem prefix, or when a row goes stale.
//!
//! | metric | kind | registered in |
//! |---|---|---|
//! | `alps_net_frames_total` | counter | `net::framing` |
//! | `alps_net_frame_bytes_total` | counter | `net::framing` |
//! | `alps_net_connections_total` | counter | `net::server` |
//! | `alps_net_connections_closed_total` | counter | `net::server` |
//! | `alps_net_refusals_total` | counter | `net::server` |
//! | `alps_serve_tokens_total` | counter | `serve::metrics` |
//! | `alps_serve_steps_total` | counter | `serve::metrics` |
//! | `alps_serve_requests_total` | counter | `serve::metrics` |
//! | `alps_serve_cancelled_total` | counter | `serve::metrics` |
//! | `alps_serve_prefills_total` | counter | `serve::metrics` |
//! | `alps_serve_prompt_tokens_total` | counter | `serve::metrics` |
//! | `alps_serve_batch_occupancy` | gauge | `serve::metrics` |
//! | `alps_serve_backend_layers` | gauge | `serve::engine` |
//! | `alps_serve_weight_bytes` | gauge | `serve::engine` |
//! | `alps_serve_step_seconds` | histogram | `serve::metrics` |
//! | `alps_serve_request_seconds` | histogram | `serve::metrics` |
//! | `alps_serve_prefill_seconds` | histogram | `serve::metrics` |
//! | `alps_coord_retries_total` | counter | `coordinator::dispatch` |
//! | `alps_coord_reroutes_total` | counter | `coordinator::dispatch` |
//! | `alps_coord_wire_tx_bytes_total` | counter | `coordinator::dispatch` |
//! | `alps_coord_rpc_seconds` | histogram | `coordinator::dispatch` |
//! | `alps_coord_fleet_size` | gauge | `coordinator::dispatch` |
//! | `alps_coord_joins_total` | counter | `coordinator::dispatch` |
//! | `alps_coord_leaves_total` | counter | `coordinator::dispatch` |
//! | `alps_prune_layers_total` | counter | `pruning::session` |
//! | `alps_prune_blocks_total` | counter | `pruning::session` |
//! | `alps_prune_checkpoints_total` | counter | `pruning::session` |
//! | `alps_prune_block` | gauge | `pruning::session` |
//! | `alps_prune_layer_solve_seconds` | histogram | `pruning::session` |
//! | `alps_prune_admm_iteration` | gauge | `pruning::status` |
//!
//! All metrics are process-global: a worker process exports its own
//! `alps_net_*`/`alps_serve_*` view, the coordinator exports the
//! pruning/dispatch view, and scraping any endpoint of a process returns
//! everything that process recorded.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod prometheus;
pub mod registry;
pub mod trace;

pub use registry::{global, Counter, Gauge, Histogram, Registry, LATENCY_EDGES};
pub use trace::Span;
