//! `obs` — the unified observability layer: one process-global metrics
//! registry, a Prometheus text encoder, and lightweight tracing spans.
//!
//! Before this module each subsystem watched itself its own way
//! (`serve/metrics.rs` sliding windows, the `pruning/status.rs` JSON
//! snapshot, bench-only timers). `obs` gives them one substrate so a
//! single scraper covers a whole fleet — a sharded 70%-sparsity pruning
//! run and a serving replica show up in the same Prometheus instance.
//!
//! * [`registry`] — atomic counters, gauges, and fixed-bucket histograms
//!   behind cloneable `Arc` handles. Registration (name + pre-declared
//!   label set) takes a lock once; recording through a handle is
//!   lock-free and allocation-free, so decode steps and ADMM inner loops
//!   can record without perturbing what they measure. [`global()`]
//!   returns the process-wide registry every endpoint renders.
//! * [`prometheus`] — text exposition (format 0.0.4): `# HELP`/`# TYPE`
//!   blocks, escaped labels, cumulative `_bucket{le=...}` histograms.
//!   Served as `GET /metrics` by all three TCP endpoints — the serve
//!   front-end (next to `/healthz`), `alps worker`, and the `prune
//!   --status-addr` server.
//! * [`trace`] — spans (monotonic start + duration + key=value fields)
//!   and point events, written as JSONL to an optional `--trace-out`
//!   sink for offline analysis; a no-op behind one atomic load otherwise.
//!
//! ## Metric naming
//!
//! `alps_<subsystem>_<name>`, with base units (seconds, bytes) and
//! `_total` on counters:
//!
//! * `alps_serve_*` — decode steps/tokens/latency, batch occupancy,
//!   prefill, admissions/evictions/cancellations;
//! * `alps_prune_*` — session progress (blocks/layers/checkpoints),
//!   per-method solve-time histograms, live ADMM iteration per worker;
//! * `alps_coord_*` — dispatcher RPC latency per worker, retries,
//!   reroutes, wire bytes by calibration encoding;
//! * `alps_net_*` — transport frames/bytes by direction, connections,
//!   refusals.
//!
//! All metrics are process-global: a worker process exports its own
//! `alps_net_*`/`alps_serve_*` view, the coordinator exports the
//! pruning/dispatch view, and scraping any endpoint of a process returns
//! everything that process recorded.

pub mod prometheus;
pub mod registry;
pub mod trace;

pub use registry::{global, Counter, Gauge, Histogram, Registry, LATENCY_EDGES};
pub use trace::Span;
