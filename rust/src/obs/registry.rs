//! Process-global metrics registry: counters, gauges, and fixed-bucket
//! histograms behind cheap cloneable handles.
//!
//! Design constraints (see the module doc in `obs/mod.rs`):
//!
//! * **Lock-free hot path.** A handle is an `Arc` around plain atomics;
//!   `inc`/`set`/`observe` never take a lock and never allocate, so the
//!   decode step and the ADMM inner loop can record per-iteration without
//!   perturbing the thing they measure.
//! * **Pre-registered labels.** Label sets are fixed at registration time
//!   ([`Registry::counter`] & co. take the full label list); the hot path
//!   only ever touches the returned handle. Dynamic label cardinality is
//!   the caller's responsibility (register per worker, not per request).
//! * **Idempotent registration.** Registering the same `(name, labels)`
//!   twice returns a handle to the *same* underlying series, so every
//!   subsystem can lazily grab its handles without coordinating
//!   initialization order. A kind conflict (e.g. a counter re-registered
//!   as a gauge) yields a detached handle that records into the void
//!   instead of panicking — observability must never take the process
//!   down.
//!
//! Rendering walks the registry under its registration mutex (scrapes are
//! rare; recording never contends with them) and hands each family to the
//! [`super::prometheus`] encoder.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Metric kind, fixed at first registration of a name.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

/// Monotonic event counter. Clone freely; clones share the same cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A handle not attached to any registry (records are dropped at
    /// render time, but `get` still works — useful in tests and as the
    /// conflict fallback).
    pub fn detached() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an `AtomicU64`).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn detached() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Shared storage of one histogram series.
pub(crate) struct HistogramCore {
    /// Ascending upper bucket bounds; an implicit `+Inf` bucket follows.
    pub(crate) edges: Vec<f64>,
    /// Per-bucket counts, `edges.len() + 1` entries (last = overflow).
    /// Stored non-cumulative; the encoder cumulates at render time.
    pub(crate) counts: Vec<AtomicU64>,
    /// Sum of observations as f64 bits, updated by CAS.
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket histogram. `observe` is lock-free: one bucket
/// `fetch_add`, one `count` `fetch_add`, and a CAS loop on the f64 sum.
#[derive(Clone)]
pub struct Histogram(pub(crate) Arc<HistogramCore>);

impl Histogram {
    pub fn detached(edges: &[f64]) -> Histogram {
        Histogram(Arc::new(HistogramCore::new(edges)))
    }

    /// Record one observation. Prometheus bucket semantics: a value lands
    /// in the first bucket whose upper bound (`le`) is `>= v`; values
    /// above every edge land in the implicit `+Inf` bucket. NaN counts
    /// toward `+Inf` (it compares greater than every edge under these
    /// rules) so a poisoned sample cannot stall the CAS or skew a finite
    /// bucket.
    pub fn observe(&self, v: f64) {
        let c = &self.0;
        let idx = c.edges.iter().position(|&e| v <= e).unwrap_or(c.edges.len());
        c.counts[idx].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match c.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// `(le, cumulative_count)` pairs, ending with the `+Inf` bucket.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let c = &self.0;
        let mut cum = 0u64;
        let mut out = Vec::with_capacity(c.edges.len() + 1);
        for (i, cnt) in c.counts.iter().enumerate() {
            cum += cnt.load(Ordering::Relaxed);
            let le = c.edges.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, cum));
        }
        out
    }
}

impl HistogramCore {
    fn new(edges: &[f64]) -> HistogramCore {
        let mut e: Vec<f64> = edges.iter().copied().filter(|x| x.is_finite()).collect();
        e.sort_by(|a, b| a.total_cmp(b));
        e.dedup();
        let counts = (0..=e.len()).map(|_| AtomicU64::new(0)).collect();
        HistogramCore {
            edges: e,
            counts,
            sum_bits: AtomicU64::new(0f64.to_bits()),
            count: AtomicU64::new(0),
        }
    }
}

/// Handle of one registered series.
enum Instrument {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Series {
    /// Sorted `(key, value)` label pairs (the registration identity).
    labels: Vec<(String, String)>,
    instrument: Instrument,
}

/// All series sharing one metric name (one `# HELP`/`# TYPE` block).
pub(crate) struct Family {
    pub(crate) name: String,
    pub(crate) help: String,
    pub(crate) kind: Kind,
    series: Vec<Series>,
}

impl Family {
    /// Iterate series as `(labels, instrument view)` for the encoder.
    pub(crate) fn each(&self, mut f: impl FnMut(&[(String, String)], SeriesView)) {
        for s in &self.series {
            let view = match &s.instrument {
                Instrument::Counter(c) => SeriesView::Counter(c.get()),
                Instrument::Gauge(g) => SeriesView::Gauge(g.get()),
                Instrument::Histogram(h) => SeriesView::Histogram {
                    buckets: h.cumulative(),
                    sum: h.sum(),
                    count: h.count(),
                },
            };
            f(&s.labels, view);
        }
    }
}

/// Snapshot of one series for rendering.
pub(crate) enum SeriesView {
    Counter(u64),
    Gauge(f64),
    Histogram { buckets: Vec<(f64, u64)>, sum: f64, count: u64 },
}

/// A set of metric families. Registration and rendering lock the family
/// list; recording through handles never does.
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

fn sorted_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut v: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    v.sort();
    v
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register (or look up) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let want = sorted_labels(labels);
        let mut fams = crate::net::lock(&self.families);
        match find_series(&mut fams, name, help, Kind::Counter, &want) {
            Found::Existing(Instrument::Counter(c)) => c.clone(),
            Found::Existing(_) | Found::Conflict => Counter::detached(),
            Found::Vacant(fam) => {
                let c = Counter::detached();
                fam.series
                    .push(Series { labels: want, instrument: Instrument::Counter(c.clone()) });
                c
            }
        }
    }

    /// Register (or look up) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let want = sorted_labels(labels);
        let mut fams = crate::net::lock(&self.families);
        match find_series(&mut fams, name, help, Kind::Gauge, &want) {
            Found::Existing(Instrument::Gauge(g)) => g.clone(),
            Found::Existing(_) | Found::Conflict => Gauge::detached(),
            Found::Vacant(fam) => {
                let g = Gauge::detached();
                fam.series.push(Series { labels: want, instrument: Instrument::Gauge(g.clone()) });
                g
            }
        }
    }

    /// Register (or look up) a histogram series with the given upper
    /// bucket bounds (a `+Inf` bucket is always appended). On lookup the
    /// first registration's edges win.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        edges: &[f64],
    ) -> Histogram {
        let want = sorted_labels(labels);
        let mut fams = crate::net::lock(&self.families);
        match find_series(&mut fams, name, help, Kind::Histogram, &want) {
            Found::Existing(Instrument::Histogram(h)) => h.clone(),
            Found::Existing(_) | Found::Conflict => Histogram::detached(edges),
            Found::Vacant(fam) => {
                let h = Histogram::detached(edges);
                fam.series
                    .push(Series { labels: want, instrument: Instrument::Histogram(h.clone()) });
                h
            }
        }
    }

    /// Render the whole registry in Prometheus text exposition format.
    pub fn render(&self) -> String {
        let fams = crate::net::lock(&self.families);
        super::prometheus::render(&fams)
    }

    /// Number of registered families (tests / introspection).
    pub fn family_count(&self) -> usize {
        crate::net::lock(&self.families).len()
    }
}

enum Found<'a> {
    Existing(&'a Instrument),
    Vacant(&'a mut Family),
    Conflict,
}

fn find_series<'a>(
    fams: &'a mut Vec<Family>,
    name: &str,
    help: &str,
    kind: Kind,
    labels: &[(String, String)],
) -> Found<'a> {
    let pos = fams.iter().position(|f| f.name == name);
    match pos {
        Some(i) if fams[i].kind != kind => Found::Conflict,
        Some(i) => {
            // NLL-friendly two-phase lookup: find the series index first.
            if let Some(j) = fams[i].series.iter().position(|s| s.labels == labels) {
                Found::Existing(&fams[i].series[j].instrument)
            } else {
                Found::Vacant(&mut fams[i])
            }
        }
        None => {
            fams.push(Family {
                name: name.to_string(),
                help: help.to_string(),
                kind,
                series: Vec::new(),
            });
            let last = fams.len() - 1;
            Found::Vacant(&mut fams[last])
        }
    }
}

/// The process-global registry every subsystem records into and every
/// `/metrics` endpoint renders.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Standard latency bucket edges in seconds: 1ms..~100s, roughly
/// exponential. Shared by RPC / solve / request histograms so dashboards
/// line up across subsystems.
pub const LATENCY_EDGES: [f64; 12] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0, 5.0, 25.0, 100.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_idempotent_registration_shares_cell() {
        let r = Registry::new();
        let a = r.counter("alps_test_total", "h", &[("k", "v")]);
        let b = r.counter("alps_test_total", "h", &[("k", "v")]);
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        assert_eq!(b.get(), 4);
        assert_eq!(r.family_count(), 1);
    }

    #[test]
    fn label_order_is_canonicalized() {
        let r = Registry::new();
        let a = r.counter("alps_t", "h", &[("a", "1"), ("b", "2")]);
        let b = r.counter("alps_t", "h", &[("b", "2"), ("a", "1")]);
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn kind_conflict_yields_detached_handle() {
        let r = Registry::new();
        let c = r.counter("alps_kind", "h", &[]);
        c.inc();
        let g = r.gauge("alps_kind", "h", &[]);
        g.set(5.0); // must not panic, must not corrupt the counter
        assert_eq!(c.get(), 1);
        assert_eq!(r.family_count(), 1);
    }

    #[test]
    fn histogram_bucket_edges_inclusive() {
        let h = Histogram::detached(&[0.1, 1.0, 10.0]);
        h.observe(0.1); // exactly on an edge -> that bucket (le semantics)
        h.observe(0.05);
        h.observe(1.0000001);
        h.observe(1e9); // beyond every edge -> +Inf
        let cum = h.cumulative();
        assert_eq!(cum.len(), 4);
        assert_eq!(cum[0], (0.1, 2)); // 0.05 and 0.1
        assert_eq!(cum[1], (1.0, 2));
        assert_eq!(cum[2], (10.0, 3));
        assert_eq!(cum[3].1, 4);
        assert!(cum[3].0.is_infinite());
        assert_eq!(h.count(), 4);
        assert!((h.sum() - (0.1 + 0.05 + 1.0000001 + 1e9)).abs() < 1e-3);
    }

    #[test]
    fn histogram_edges_sorted_and_deduped() {
        let h = Histogram::detached(&[5.0, 1.0, 5.0, f64::INFINITY]);
        h.observe(2.0);
        let cum = h.cumulative();
        // finite edges 1, 5 plus implicit +Inf (the explicit Inf dropped)
        assert_eq!(cum.len(), 3);
        assert_eq!(cum[0].0, 1.0);
        assert_eq!(cum[1], (5.0, 1));
    }

    #[test]
    fn histogram_nan_goes_to_overflow() {
        let h = Histogram::detached(&[1.0]);
        h.observe(f64::NAN);
        let cum = h.cumulative();
        assert_eq!(cum[0].1, 0);
        assert_eq!(cum[1].1, 1);
    }

    #[test]
    fn concurrent_recording_is_exact() {
        let r = Registry::new();
        let c = r.counter("alps_conc_total", "h", &[]);
        let h = r.histogram("alps_conc_secs", "h", &[], &[0.5]);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        c.inc();
                        h.observe(if i % 2 == 0 { 0.25 } else { 0.75 });
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
        assert_eq!(h.count(), 8000);
        let cum = h.cumulative();
        assert_eq!(cum[0].1, 4000);
        assert_eq!(cum[1].1, 8000);
        assert!((h.sum() - (4000.0 * 0.25 + 4000.0 * 0.75)).abs() < 1e-6);
    }

    #[test]
    fn gauge_set_get() {
        let r = Registry::new();
        let g = r.gauge("alps_g", "h", &[]);
        g.set(-2.5);
        assert_eq!(g.get(), -2.5);
        let g2 = r.gauge("alps_g", "h", &[]);
        assert_eq!(g2.get(), -2.5);
    }
}
