//! Prometheus text exposition (format version 0.0.4) for the
//! [`super::registry`] families.
//!
//! One `# HELP` / `# TYPE` block per family, one sample line per series
//! (histograms expand to cumulative `_bucket{le="..."}` lines plus
//! `_sum` / `_count`). Label values are escaped per the spec
//! (`\\` -> `\\\\`, `"` -> `\\"`, newline -> `\\n`); HELP text escapes
//! backslash and newline. The encoder trusts metric *names* — they are
//! compile-time constants in this crate (`alps_<subsystem>_<name>`),
//! never user input.
//!
//! Serve `render()`'s output with content type
//! [`CONTENT_TYPE`] (`text/plain; version=0.0.4`).

use super::registry::{Family, Kind, SeriesView};
use std::fmt::Write as _;

/// HTTP content type for the exposition format.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Escape a label value: backslash, double quote, newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape HELP text: backslash and newline (quotes are legal there).
pub fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Format a sample value: integral floats render without a fraction,
/// non-finite values use Prometheus spellings (`+Inf`, `-Inf`, `NaN`).
pub fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render a family list (called by [`super::Registry::render`] under the
/// registration lock).
pub(crate) fn render(families: &[Family]) -> String {
    let mut out = String::new();
    for fam in families {
        let kind = match fam.kind {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        };
        let _ = writeln!(out, "# HELP {} {}", fam.name, escape_help(&fam.help));
        let _ = writeln!(out, "# TYPE {} {}", fam.name, kind);
        fam.each(|labels, view| match view {
            SeriesView::Counter(v) => {
                let _ = writeln!(out, "{}{} {}", fam.name, label_block(labels, None), v);
            }
            SeriesView::Gauge(v) => {
                let _ =
                    writeln!(out, "{}{} {}", fam.name, label_block(labels, None), fmt_value(v));
            }
            SeriesView::Histogram { buckets, sum, count } => {
                for (le, cum) in &buckets {
                    let _ = writeln!(
                        out,
                        "{}_bucket{} {}",
                        fam.name,
                        label_block(labels, Some(("le", &fmt_value(*le)))),
                        cum
                    );
                }
                let _ = writeln!(
                    out,
                    "{}_sum{} {}",
                    fam.name,
                    label_block(labels, None),
                    fmt_value(sum)
                );
                let _ =
                    writeln!(out, "{}_count{} {}", fam.name, label_block(labels, None), count);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::Registry;
    use super::*;

    #[test]
    fn escaping() {
        assert_eq!(escape_label("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        assert_eq!(escape_help("a\\b\"c\nd"), "a\\\\b\"c\\nd");
    }

    #[test]
    fn value_formats() {
        assert_eq!(fmt_value(3.0), "3");
        assert_eq!(fmt_value(0.25), "0.25");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(f64::NAN), "NaN");
    }

    #[test]
    fn counter_and_gauge_lines() {
        let r = Registry::new();
        r.counter("alps_x_total", "events", &[("dir", "tx")]).add(7);
        r.gauge("alps_x_live", "live \"now\"\nyes", &[]).set(2.5);
        let text = r.render();
        assert!(text.contains("# HELP alps_x_total events\n"), "{text}");
        assert!(text.contains("# TYPE alps_x_total counter\n"));
        assert!(text.contains("alps_x_total{dir=\"tx\"} 7\n"));
        assert!(text.contains("# TYPE alps_x_live gauge\n"));
        assert!(text.contains("# HELP alps_x_live live \"now\"\\nyes\n"));
        assert!(text.contains("alps_x_live 2.5\n"));
    }

    #[test]
    fn histogram_exposition_is_cumulative_with_inf() {
        let r = Registry::new();
        let h = r.histogram("alps_x_seconds", "lat", &[("m", "alps")], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(2.0);
        let text = r.render();
        assert!(text.contains("# TYPE alps_x_seconds histogram\n"));
        assert!(text.contains("alps_x_seconds_bucket{m=\"alps\",le=\"0.1\"} 1\n"), "{text}");
        assert!(text.contains("alps_x_seconds_bucket{m=\"alps\",le=\"1\"} 2\n"));
        assert!(text.contains("alps_x_seconds_bucket{m=\"alps\",le=\"+Inf\"} 3\n"));
        assert!(text.contains("alps_x_seconds_sum{m=\"alps\"} 2.55\n"));
        assert!(text.contains("alps_x_seconds_count{m=\"alps\"} 3\n"));
    }

    #[test]
    fn every_series_line_parses_shapewise() {
        // cheap structural lint: every non-comment line is `name{...} value`
        // or `name value` with a parseable float
        let r = Registry::new();
        r.counter("alps_a_total", "h", &[]).inc();
        r.gauge("alps_b", "h", &[("w", "x:1")]).set(1.5);
        r.histogram("alps_c_seconds", "h", &[], &[0.5]).observe(0.1);
        for line in r.render().lines() {
            if line.starts_with('#') {
                continue;
            }
            let (_, val) = line.rsplit_once(' ').expect("name value");
            assert!(
                val.parse::<f64>().is_ok() || val == "+Inf" || val == "NaN",
                "bad value in {line}"
            );
        }
    }
}
