//! Lightweight tracing: spans with monotonic start/duration plus
//! key=value events, emitted as JSONL to an optional file sink.
//!
//! The sink is process-global and installed at most once (from
//! `--trace-out`); until then every span/event is a no-op behind a single
//! relaxed atomic load, so instrumented hot paths cost nothing in
//! untraced runs. Timestamps are seconds since the **process epoch** (the
//! first call into this module), from a monotonic clock — they order
//! events within one process and never go backwards, but are not wall
//! times.
//!
//! Record shapes (one JSON object per line):
//!
//! ```text
//! {"ts":12.081,"kind":"span","name":"block","dur":3.402,"block":"7"}
//! {"ts":12.114,"kind":"event","name":"layer_solved","layer":"mlp.w1"}
//! ```
//!
//! Writes go through one `Mutex<BufWriter<File>>`; tracing is for
//! coarse-grained structure (blocks, layers, requests), not per-token
//! firehoses, so the lock is uncontended in practice. A write error
//! disables the sink rather than failing the traced operation.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: OnceLock<Mutex<BufWriter<File>>> = OnceLock::new();

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Seconds since the process epoch (monotonic, starts near 0).
pub fn elapsed_secs() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// Install the JSONL sink. Only the first successful install wins;
/// later calls return an error instead of silently redirecting.
pub fn install_sink(path: &str) -> std::io::Result<()> {
    let file = File::create(path)?;
    if SINK.set(Mutex::new(BufWriter::new(file))).is_err() {
        return Err(std::io::Error::other("trace sink already installed"));
    }
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Is a sink installed? (One relaxed load — the hot-path guard.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_record(kind: &str, name: &str, dur: Option<f64>, fields: &[(String, String)]) {
    if !enabled() {
        return;
    }
    let mut line = format!(
        "{{\"ts\":{:.6},\"kind\":\"{kind}\",\"name\":\"{}\"",
        elapsed_secs(),
        json_escape(name)
    );
    if let Some(d) = dur {
        line.push_str(&format!(",\"dur\":{d:.6}"));
    }
    for (k, v) in fields {
        line.push_str(&format!(",\"{}\":\"{}\"", json_escape(k), json_escape(v)));
    }
    line.push_str("}\n");
    if let Some(sink) = SINK.get() {
        let mut w = crate::net::lock(sink);
        if w.write_all(line.as_bytes()).and_then(|_| w.flush()).is_err() {
            // dead sink (disk full, closed fd): stop tracing, keep running
            ENABLED.store(false, Ordering::Release);
        }
    }
}

/// Emit a standalone point event with key=value fields.
pub fn event(name: &str, fields: &[(&str, &str)]) {
    if !enabled() {
        return;
    }
    let owned: Vec<(String, String)> =
        fields.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    write_record("event", name, None, &owned);
}

/// An in-progress span. Created with [`Span::begin`]; the record (with
/// duration) is emitted by [`Span::end`] or on drop. All methods are
/// no-ops while no sink is installed.
pub struct Span {
    name: String,
    start: Instant,
    fields: Vec<(String, String)>,
    emitted: bool,
}

impl Span {
    pub fn begin(name: &str) -> Span {
        Span { name: name.to_string(), start: Instant::now(), fields: Vec::new(), emitted: false }
    }

    /// Attach a key=value field to the span record (builder-style).
    pub fn field(mut self, k: &str, v: &str) -> Span {
        if enabled() {
            self.fields.push((k.to_string(), v.to_string()));
        }
        self
    }

    /// Attach a field to a span held by reference.
    pub fn set_field(&mut self, k: &str, v: &str) {
        if enabled() {
            self.fields.push((k.to_string(), v.to_string()));
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Finish the span, emitting its record.
    pub fn end(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        if self.emitted {
            return;
        }
        self.emitted = true;
        let dur = self.start.elapsed().as_secs_f64();
        write_record("span", &self.name, Some(dur), &self.fields);
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let a = elapsed_secs();
        let b = elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn span_noop_without_sink() {
        // installing a sink in tests would poison every other test in the
        // process (the sink is global), so only the disabled path is unit
        // tested here; the installed path is covered by the CLI
        // integration (`--trace-out`) and by `fields_skipped_when_disabled`
        let s = Span::begin("x").field("k", "v");
        assert!(s.elapsed_secs() >= 0.0);
        s.end();
        event("e", &[("a", "b")]);
    }

    #[test]
    fn fields_skipped_when_disabled() {
        let s = Span::begin("x").field("k", "v");
        assert!(s.fields.is_empty());
    }

    #[test]
    fn json_escape_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
