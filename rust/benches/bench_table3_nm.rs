//! Table 3 (+ Tables 10-11): N:M structured sparsity (2:4 and 4:8) across
//! methods — perplexity and zero-shot on the pruned model.
//!
//!     cargo bench --bench bench_table3_nm

use alps::bench::artifacts_ready;
use alps::config::SparsityTarget;
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::Model;
use alps::pruning::{MethodSpec, PruneSession};
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    if !artifacts_ready() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let model_name = std::env::var("ALPS_MODEL").unwrap_or_else(|_| "alps-tiny".into());
    let dir = Path::new("artifacts");
    let corpus = Corpus::load(&dir.join("corpus.bin"))?;
    let dense = Model::load(dir, &model_name)?;
    let calib = sample_windows(corpus.split("train")?, 16, dense.cfg.seq_len, 0xCA11B);
    let eval_ids = corpus.split("wikitext2-like")?;

    println!("== Table 3: N:M sparsity on {model_name} ==\n");
    let mut table = Table::new(&[
        "pattern", "method", "wikitext2↓", "ptb↓", "c4↓", "piqa↑", "arc-e↑", "arc-c↑",
    ]);
    for pattern in ["2:4", "4:8"] {
        let target = SparsityTarget::parse(pattern)?;
        for spec in MethodSpec::all() {
            let method = spec.label();
            let mut model = Model::load(dir, &model_name)?;
            PruneSession::builder()
                .calib(calib.clone())
                .target(target)
                .method(spec.clone())
                .run(&mut model)?;
            // hardware-pattern validity is part of the benchmark contract
            for name in model.prunable_names() {
                assert!(alps::pruning::check_target(
                    &model.weights.matrix(&name)?,
                    target
                ));
            }
            let mut row = vec![pattern.to_string(), method.to_string()];
            for split in Corpus::eval_split_names() {
                row.push(fmt_sig(perplexity(&model, corpus.split(split)?)?));
            }
            for task in [
                tasks::piqa_like(eval_ids, 30, 32, 6, 21),
                tasks::arc_easy_like(eval_ids, 30, 32, 6, 22),
                tasks::arc_challenge_like(eval_ids, 30, 32, 6, 23),
            ] {
                row.push(format!("{:.1}", zero_shot_accuracy(&model, &task)? * 100.0));
            }
            table.row(&row);
            eprintln!("  done {pattern} {method}");
        }
    }
    table.print();
    println!("\npaper shape: ALPS best on most N:M cells, larger margins than at equal unstructured sparsity.");
    Ok(())
}
