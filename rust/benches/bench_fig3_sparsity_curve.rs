//! Figure 3: perplexity and PIQA-like accuracy vs sparsity (0.4 -> 0.9)
//! for one model, all methods — the curve where ALPS's advantage widens.
//!
//!     cargo bench --bench bench_fig3_sparsity_curve
//!     ALPS_MODEL=alps-small cargo bench --bench bench_fig3_sparsity_curve

use alps::bench::artifacts_ready;
use alps::config::SparsityTarget;
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::Model;
use alps::pruning::{MethodSpec, PruneSession};
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    if !artifacts_ready() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let model_name = std::env::var("ALPS_MODEL").unwrap_or_else(|_| "alps-tiny".into());
    let dir = Path::new("artifacts");
    let corpus = Corpus::load(&dir.join("corpus.bin"))?;
    let dense = Model::load(dir, &model_name)?;
    let calib = sample_windows(corpus.split("train")?, 16, dense.cfg.seq_len, 0xCA11B);
    let eval_ids = corpus.split("wikitext2-like")?;
    let piqa = tasks::piqa_like(eval_ids, 40, 32, 6, 11);

    println!("== Figure 3: {model_name} — ppl (left) and piqa-like acc (right) vs sparsity ==\n");
    let methods = ["mp", "wanda", "sparsegpt", "dsnot", "alps"];
    let mut ppl_table = Table::new(&["sparsity", "MP", "Wanda", "SparseGPT", "DSnoT", "ALPS"]);
    let mut acc_table = Table::new(&["sparsity", "MP", "Wanda", "SparseGPT", "DSnoT", "ALPS"]);
    for s in [0.4f64, 0.5, 0.6, 0.7, 0.8, 0.9] {
        let target = SparsityTarget::Unstructured(s);
        let mut ppl_row = vec![format!("{s:.1}")];
        let mut acc_row = vec![format!("{s:.1}")];
        for method in methods {
            let mut model = Model::load(dir, &model_name)?;
            PruneSession::builder()
                .calib(calib.clone())
                .target(target)
                .method(MethodSpec::parse(method)?)
                .run(&mut model)?;
            ppl_row.push(fmt_sig(perplexity(&model, eval_ids)?));
            acc_row.push(format!("{:.1}", zero_shot_accuracy(&model, &piqa)? * 100.0));
            eprintln!("  done s={s} {method}");
        }
        ppl_table.row(&ppl_row);
        acc_table.row(&acc_row);
    }
    println!("WikiText2-like perplexity (lower better):");
    ppl_table.print();
    println!("\nPIQA-like accuracy % (higher better):");
    acc_table.print();
    println!("\npaper shape: methods tie at s<=0.5, ALPS pulls ahead from 0.6, dramatically by 0.8-0.9.");
    Ok(())
}
