//! §Perf: hot-path microbenchmarks across the three layers —
//! (L3) native matmul / eigh / ADMM-iteration throughput,
//! (L2/L1) HLO artifact execution latency per ADMM iteration and per
//! 10-iteration PCG refine, plus the end-to-end per-layer ALPS cost on
//! real shapes. Results feed EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench bench_perf_hotpath

use alps::bench::{bench, synthetic_problem};
use alps::config::{AlpsConfig, SparsityTarget};
use alps::linalg::matmul::matmul;
use alps::linalg::{Matrix, SymEig};
use alps::pruning::alps::{Alps, DiagScaling};
use alps::pruning::projection::topk_project;
use alps::runtime::client::Value;
use alps::runtime::executor::AlpsHlo;
use alps::runtime::{Manifest, Runtime};
use alps::util::table::Table;
use alps::util::Rng;
use std::path::Path;

fn gflops(flops: f64, secs: f64) -> String {
    format!("{:.2}", flops / secs / 1e9)
}

fn main() -> anyhow::Result<()> {
    println!("== §Perf: hot-path benchmarks ==\n");
    let mut rng = Rng::new(0);

    // ---------- L3 native matmul
    println!("L3 native matmul (threaded, blocked):");
    let mut t = Table::new(&["shape", "median s", "GFLOP/s"]);
    for &(m, k, n) in &[(512usize, 512usize, 512usize), (1024, 1024, 256), (4096, 256, 1024)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let stats = bench(1, 5, || matmul(&a, &b));
        let flops = 2.0 * (m * k * n) as f64;
        t.row(&[
            format!("{m}x{k}x{n}"),
            format!("{:.4}", stats.median()),
            gflops(flops, stats.median()),
        ]);
    }
    t.print();

    // ---------- L3 eigh (the once-per-layer factorization)
    println!("\nL3 eigh (tred2+tql2, f64):");
    let mut t = Table::new(&["n", "median s"]);
    for &n in &[128usize, 256, 512] {
        let x = Matrix::randn(n + 32, n, &mut rng);
        let h = alps::linalg::matmul::gram(&x);
        let stats = bench(0, 3, || SymEig::new(&h).unwrap());
        t.row(&[n.to_string(), format!("{:.3}", stats.median())]);
    }
    t.print();

    // ---------- L3 ADMM iteration (native) vs L2/L1 (HLO artifact)
    println!("\nADMM iteration: native vs HLO artifact (128x128, 256x1024):");
    let mut t = Table::new(&["shape", "native s/iter", "hlo s/iter", "hlo/native"]);
    let rt = if Path::new("artifacts/manifest.json").exists() {
        Some(Runtime::new(Path::new("artifacts"))?)
    } else {
        None
    };
    for &(n_in, n_out) in &[(128usize, 128usize), (256, 1024)] {
        let p = synthetic_problem(n_in, n_out, 2 * n_in, 1);
        let (scaling, hs) = DiagScaling::from_gram(&p.h, 1e-2);
        let gs = scaling.scale_g(&p.g);
        let eig = SymEig::new(&hs)?;
        let k = (0.3 * (n_in * n_out) as f64) as usize;
        let d0 = scaling.to_scaled(&p.what);
        let v0 = Matrix::zeros(n_in, n_out);

        // native: ridge solve + projection + dual update
        let native = bench(1, 5, || {
            let mut b = gs.sub(&v0);
            b.axpy(1.0, &d0);
            let w = eig.ridge_solve(1.0, &b);
            let mut z = w.clone();
            z.axpy(1.0, &v0);
            let d = topk_project(&z, k);
            let mut wd = w.sub(&d);
            wd = wd.scale(1.0);
            std::hint::black_box(v0.add(&wd))
        });

        let hlo_cell = if let Some(rt) = &rt {
            let name = Manifest::admm_iter_name(n_in, n_out);
            if rt.has(&name) {
                let inputs = vec![
                    Value::matrix(&eig.q),
                    Value::vector(&eig.vals),
                    Value::matrix(&gs),
                    Value::matrix(&d0),
                    Value::matrix(&v0),
                    Value::scalar(1.0),
                    Value::I32(k as i32),
                ];
                let stats = bench(2, 5, || rt.run(&name, &inputs).unwrap());
                Some(stats.median())
            } else {
                None
            }
        } else {
            None
        };
        let (hlo_s, ratio) = match hlo_cell {
            Some(s) => (format!("{s:.4}"), format!("{:.2}x", s / native.median())),
            None => ("n/a".into(), "n/a".into()),
        };
        t.row(&[
            format!("{n_in}x{n_out}"),
            format!("{:.4}", native.median()),
            hlo_s,
            ratio,
        ]);
    }
    t.print();

    // ---------- PCG refinement hot path (Table 1 right's engine)
    println!("\nPCG refine (10 iters) — the Alg. 2 hot path:");
    let mut t = Table::new(&["shape", "median s", "GFLOP/s (matmul bound)"]);
    for &(n_in, n_out) in &[(512usize, 512usize), (1024, 512)] {
        let p = synthetic_problem(n_in, n_out, 2 * n_in, 3);
        let w0 = topk_project(&p.what, n_in * n_out / 2);
        let mask = w0.support_mask();
        let stats = bench(1, 3, || {
            alps::linalg::solve::pcg_support(&p.h, &p.g, &w0, &mask, 10, 1e-12)
        });
        let flops = 10.0 * 2.0 * (n_in * n_in * n_out) as f64;
        t.row(&[
            format!("{n_in}x{n_out}"),
            format!("{:.4}", stats.median()),
            gflops(flops, stats.median()),
        ]);
    }
    t.print();

    // ---------- full per-layer ALPS cost (native vs hlo)
    println!("\nend-to-end ALPS per layer (0.7 sparsity):");
    let mut t = Table::new(&["shape", "engine", "s/layer", "admm iters"]);
    for &(n_in, n_out) in &[(128usize, 512usize), (256, 1024)] {
        let p = synthetic_problem(n_in, n_out, 2 * n_in, 2);
        let target = SparsityTarget::Unstructured(0.7);
        let stats = bench(0, 2, || Alps::default().prune_traced(&p, target).unwrap());
        let (_, trace) = Alps::default().prune_traced(&p, target)?;
        t.row(&[
            format!("{n_in}x{n_out}"),
            "native".into(),
            format!("{:.3}", stats.median()),
            trace.admm_iters.to_string(),
        ]);
        if let Some(rt) = &rt {
            let hlo = AlpsHlo { rt, cfg: AlpsConfig::default() };
            if hlo.supports(n_in, n_out, target) {
                let stats = bench(0, 2, || hlo.prune_traced(&p, target).unwrap());
                let (_, trace) = hlo.prune_traced(&p, target)?;
                t.row(&[
                    format!("{n_in}x{n_out}"),
                    "hlo".into(),
                    format!("{:.3}", stats.median()),
                    trace.admm_iters.to_string(),
                ]);
            }
        }
    }
    t.print();

    if let Some(rt) = &rt {
        println!("\ntotal artifact executions this run: {}", rt.total_execs());
    }
    Ok(())
}
